//! `minloom` — a vendored deterministic-interleaving model checker for
//! the repo's hand-rolled concurrency (the pool protocol in
//! [`util::parallel`](crate::util::parallel) and the striped registry in
//! [`obs::registry`](crate::obs::registry)).
//!
//! The idea (a small subset of `loom`): production code is written
//! against type aliases that resolve to `std::sync` types normally and
//! to the [`shim`] types under `--features minloom`. Each shim operation
//! is a *decision point*: a cooperative kernel (real OS threads, but
//! exactly one runnable task executing at a time) picks which task runs
//! next, records the choice, and [`Checker::try_check`] replays the
//! program under every schedule a bounded DFS can reach. A run that
//! deadlocks, loses an update (caught by an `assert!` in the modeled
//! protocol), or panics surfaces as a [`Violation`] carrying the
//! schedule trace that produced it.
//!
//! Exploration is kept tractable by *preemption bounding* (Musuvathi &
//! Qadeer): the currently running task is preferred, and once a run has
//! spent [`Checker::max_preemptions`] involuntary context switches the
//! scheduler stops introducing new ones. Small protocol models (2–3
//! tasks, tens of operations) exhaust in hundreds to thousands of
//! schedules.
//!
//! Deliberate limitations, documented in `docs/ANALYSIS.md`:
//!
//! * **Sequentially consistent exploration only.** Shim atomics accept
//!   an `Ordering` argument for source compatibility but the checker
//!   does not simulate weak-memory reorderings; it explores thread
//!   interleavings, not relaxed-memory behaviors.
//! * **No spurious condvar wakeups.** A shimmed `Condvar::wait` only
//!   returns after a notify (std permits spurious returns).
//! * Shim types **pass through** to plain `std::sync` behavior on any
//!   thread not owned by a running model, so feature-unified test runs
//!   (`cargo test --features minloom`) leave the production pool intact.

use std::cell::{Cell, RefCell};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::{Arc, Condvar as OsCondvar, Mutex as OsMutex, MutexGuard as OsMutexGuard, Once};

type TaskId = usize;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TaskState {
    Runnable,
    BlockedMutex(usize),
    BlockedCondvar(usize),
    BlockedJoin(TaskId),
    Finished,
}

/// One scheduling decision: how many tasks were eligible and which
/// position the scheduler took. The DFS backtracks over `pos`.
#[derive(Debug, Clone, Copy)]
struct Decision {
    enabled: usize,
    pos: usize,
}

/// What the checker found on a failing schedule.
#[derive(Debug, Clone)]
pub enum Violation {
    /// No task is runnable but at least one has not finished.
    Deadlock { blocked: Vec<String>, trace: Vec<String> },
    /// A single schedule exceeded [`Checker::max_ops`] shim operations.
    StepBound { ops: usize, trace: Vec<String> },
    /// A modeled task panicked (e.g. an `assert!` on a protocol
    /// invariant observed a lost update).
    TaskPanic { task: TaskId, message: String, trace: Vec<String> },
}

fn fmt_trace(f: &mut fmt::Formatter<'_>, trace: &[String]) -> fmt::Result {
    let tail = trace.len().saturating_sub(24);
    if tail > 0 {
        write!(f, " [… {tail} earlier ops]")?;
    }
    for op in &trace[tail..] {
        write!(f, " → {op}")?;
    }
    Ok(())
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Violation::Deadlock { blocked, trace } => {
                write!(f, "deadlock: unfinished tasks [{}]; schedule:", blocked.join(", "))?;
                fmt_trace(f, trace)
            }
            Violation::StepBound { ops, trace } => {
                write!(f, "step bound exceeded after {ops} ops; schedule:")?;
                fmt_trace(f, trace)
            }
            Violation::TaskPanic { task, message, trace } => {
                write!(f, "task t{task} panicked: {message}; schedule:")?;
                fmt_trace(f, trace)
            }
        }
    }
}

/// Result of a completed (violation-free) exploration.
#[derive(Debug, Clone, Copy)]
pub struct Report {
    /// Schedules actually executed.
    pub schedules: usize,
    /// True when the bounded DFS exhausted every reachable schedule
    /// within [`Checker::max_schedules`].
    pub complete: bool,
}

struct ExecState {
    tasks: Vec<TaskState>,
    current: Option<TaskId>,
    /// decision positions to replay from the previous run (DFS prefix)
    replay: Vec<usize>,
    replay_idx: usize,
    /// decisions taken this run, consumed by the DFS to backtrack
    decisions: Vec<Decision>,
    /// human-readable op log for violation reports
    trace: Vec<String>,
    mutex_owner: Vec<Option<TaskId>>,
    cv_waiters: Vec<Vec<TaskId>>,
    violation: Option<Violation>,
    ops: usize,
    preemptions: usize,
    max_preemptions: usize,
    max_ops: usize,
}

struct Kernel {
    state: OsMutex<ExecState>,
    cv: OsCondvar,
    /// distinguishes shim-object registrations across runs
    epoch: u64,
}

static NEXT_EPOCH: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// (kernel, task id) of the model run owning this thread, if any.
    static CTX: RefCell<Option<(Arc<Kernel>, TaskId)>> = const { RefCell::new(None) };
    /// suppress panic-hook output for intentional in-model panics
    static SUPPRESS_PANIC_OUTPUT: Cell<bool> = const { Cell::new(false) };
}

fn ctx() -> Option<(Arc<Kernel>, TaskId)> {
    CTX.with(|c| c.borrow().clone())
}

/// Panic payload used to unwind tasks out of an aborted run.
struct AbortRun;

fn abort_run() -> ! {
    std::panic::panic_any(AbortRun)
}

fn payload_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn install_panic_hook() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if !SUPPRESS_PANIC_OUTPUT.with(|s| s.get()) {
                prev(info);
            }
        }));
    });
}

fn klock(k: &Kernel) -> OsMutexGuard<'_, ExecState> {
    // a task panicking while holding the kernel lock poisons it; every
    // accessor recovers because the state itself stays consistent
    k.state.lock().unwrap_or_else(|e| e.into_inner())
}

/// Pick the next task to run. Called with the kernel lock held, at
/// every decision point (shim op, block, finish).
fn pick_locked(st: &mut ExecState) {
    let runnable: Vec<TaskId> = st
        .tasks
        .iter()
        .enumerate()
        .filter(|(_, t)| matches!(t, TaskState::Runnable))
        .map(|(i, _)| i)
        .collect();
    if runnable.is_empty() {
        let blocked: Vec<String> = st
            .tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| !matches!(t, TaskState::Finished))
            .map(|(i, t)| format!("t{i}:{t:?}"))
            .collect();
        if !blocked.is_empty() && st.violation.is_none() {
            st.violation =
                Some(Violation::Deadlock { blocked, trace: st.trace.clone() });
        }
        st.current = None;
        return;
    }
    let mut enabled = runnable;
    let cur = st.current;
    if let Some(c) = cur {
        if let Some(p) = enabled.iter().position(|&t| t == c) {
            // prefer continuing the current task; once the preemption
            // budget is spent, never switch away from a runnable task
            enabled.remove(p);
            enabled.insert(0, c);
            if st.preemptions >= st.max_preemptions {
                enabled.truncate(1);
            }
        }
    }
    let pos = if st.replay_idx < st.replay.len() {
        st.replay[st.replay_idx]
    } else {
        0
    };
    st.replay_idx += 1;
    debug_assert!(pos < enabled.len(), "replay diverged: {pos} >= {}", enabled.len());
    st.decisions.push(Decision { enabled: enabled.len(), pos });
    let chosen = enabled[pos];
    if let Some(c) = cur {
        if chosen != c && matches!(st.tasks[c], TaskState::Runnable) {
            st.preemptions += 1;
        }
    }
    st.current = Some(chosen);
}

/// Decision point before every shim operation: log it, reschedule, and
/// wait until this task is current again.
fn yield_op(k: &Kernel, me: TaskId, label: &str) {
    let mut st = klock(k);
    if st.violation.is_some() {
        drop(st);
        abort_run();
    }
    st.ops += 1;
    if st.ops > st.max_ops {
        st.violation = Some(Violation::StepBound { ops: st.ops, trace: st.trace.clone() });
        k.cv.notify_all();
        drop(st);
        abort_run();
    }
    st.trace.push(format!("t{me} {label}"));
    pick_locked(&mut st);
    k.cv.notify_all();
    while st.current != Some(me) {
        if st.violation.is_some() {
            drop(st);
            abort_run();
        }
        st = k.cv.wait(st).unwrap_or_else(|e| e.into_inner());
    }
}

/// Park `me` as `blocked_as` and wait to be made runnable and current.
/// The caller must have arranged for some other task to wake it.
fn block_current(k: &Kernel, me: TaskId, blocked_as: TaskState) {
    let mut st = klock(k);
    st.tasks[me] = blocked_as;
    pick_locked(&mut st);
    k.cv.notify_all();
    loop {
        if st.violation.is_some() {
            drop(st);
            abort_run();
        }
        if matches!(st.tasks[me], TaskState::Runnable) && st.current == Some(me) {
            return;
        }
        st = k.cv.wait(st).unwrap_or_else(|e| e.into_inner());
    }
}

fn wake_mutex_waiters(st: &mut ExecState, mid: usize) {
    for t in st.tasks.iter_mut() {
        if matches!(*t, TaskState::BlockedMutex(m) if m == mid) {
            *t = TaskState::Runnable;
        }
    }
}

/// Grant `me` logical ownership of mutex `mid`, blocking (and letting
/// the scheduler explore) while another task owns it.
fn acquire_mutex(k: &Kernel, me: TaskId, mid: usize) {
    loop {
        {
            let mut st = klock(k);
            if st.violation.is_some() {
                drop(st);
                abort_run();
            }
            if st.mutex_owner[mid].is_none() {
                st.mutex_owner[mid] = Some(me);
                return;
            }
        }
        // owned by someone else: park until a release wakes us, then
        // re-contend (the scheduler decides who wins)
        block_current(k, me, TaskState::BlockedMutex(mid));
    }
}

fn release_mutex(k: &Kernel, mid: usize) {
    let mut st = klock(k);
    st.mutex_owner[mid] = None;
    wake_mutex_waiters(&mut st, mid);
    k.cv.notify_all();
}

/// Mark `me` finished, wake joiners, and hand the schedule onward.
fn finish_task(k: &Kernel, me: TaskId) {
    let mut st = klock(k);
    st.tasks[me] = TaskState::Finished;
    for t in st.tasks.iter_mut() {
        if matches!(*t, TaskState::BlockedJoin(j) if j == me) {
            *t = TaskState::Runnable;
        }
    }
    if st.violation.is_none() {
        pick_locked(&mut st);
    }
    k.cv.notify_all();
}

/// Record a task panic as the run's violation (first panic wins).
fn record_panic(k: &Kernel, me: TaskId, p: Box<dyn std::any::Any + Send>) {
    let mut st = klock(k);
    st.tasks[me] = TaskState::Finished;
    if p.downcast_ref::<AbortRun>().is_none() && st.violation.is_none() {
        let message = payload_msg(&p);
        st.violation =
            Some(Violation::TaskPanic { task: me, message, trace: st.trace.clone() });
    }
    st.current = None;
    k.cv.notify_all();
}

/// Serializes concurrent `model()` calls from parallel `cargo test`
/// threads — the checker owns process-wide panic-hook state and the
/// schedules themselves must not interleave.
static MODEL_LOCK: OsMutex<()> = OsMutex::new(());

/// Bounded-DFS schedule explorer. `Default` gives budgets sized for the
/// repo's protocol models (2–3 tasks, tens of shim ops each).
#[derive(Debug, Clone, Copy)]
pub struct Checker {
    /// stop exploring (reporting `complete: false`) after this many runs
    pub max_schedules: usize,
    /// involuntary context switches allowed per schedule
    pub max_preemptions: usize,
    /// shim-operation budget per schedule (guards accidental livelock)
    pub max_ops: usize,
}

impl Default for Checker {
    fn default() -> Self {
        Checker { max_schedules: 8192, max_preemptions: 2, max_ops: 20_000 }
    }
}

impl Checker {
    /// Explore `f` under every reachable bounded schedule, panicking
    /// with the violating trace if one is found.
    pub fn check<F: Fn()>(&self, f: F) -> Report {
        match self.try_check(f) {
            Ok(r) => r,
            Err(v) => panic!("model checking found a violation: {v}"),
        }
    }

    /// Like [`Checker::check`] but returns the violation for tests that
    /// expect one (the seeded-bug corpus).
    pub fn try_check<F: Fn()>(&self, f: F) -> Result<Report, Violation> {
        let _serial = MODEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        assert!(ctx().is_none(), "nested model() is not supported");
        install_panic_hook();
        let mut replay: Vec<usize> = Vec::new();
        let mut schedules = 0usize;
        loop {
            schedules += 1;
            let (decisions, violation) = self.run_schedule(&f, &replay);
            if let Some(v) = violation {
                return Err(v);
            }
            // backtrack: deepest decision with an unexplored alternative
            let mut next: Option<Vec<usize>> = None;
            for i in (0..decisions.len()).rev() {
                if decisions[i].pos + 1 < decisions[i].enabled {
                    let mut r: Vec<usize> =
                        decisions[..i].iter().map(|d| d.pos).collect();
                    r.push(decisions[i].pos + 1);
                    next = Some(r);
                    break;
                }
            }
            match next {
                None => return Ok(Report { schedules, complete: true }),
                Some(_) if schedules >= self.max_schedules => {
                    return Ok(Report { schedules, complete: false });
                }
                Some(r) => replay = r,
            }
        }
    }

    /// Run `f` once as task 0 under the given replay prefix.
    fn run_schedule<F: Fn()>(
        &self,
        f: &F,
        replay: &[usize],
    ) -> (Vec<Decision>, Option<Violation>) {
        let kernel = Arc::new(Kernel {
            state: OsMutex::new(ExecState {
                tasks: vec![TaskState::Runnable],
                current: Some(0),
                replay: replay.to_vec(),
                replay_idx: 0,
                decisions: Vec::new(),
                trace: Vec::new(),
                mutex_owner: Vec::new(),
                cv_waiters: Vec::new(),
                violation: None,
                ops: 0,
                preemptions: 0,
                max_preemptions: self.max_preemptions,
                max_ops: self.max_ops,
            }),
            cv: OsCondvar::new(),
            epoch: NEXT_EPOCH.fetch_add(1, AtomicOrdering::Relaxed),
        });
        CTX.with(|c| *c.borrow_mut() = Some((Arc::clone(&kernel), 0)));
        let was = SUPPRESS_PANIC_OUTPUT.with(|s| s.replace(true));
        let res = catch_unwind(AssertUnwindSafe(f));
        SUPPRESS_PANIC_OUTPUT.with(|s| s.set(was));
        CTX.with(|c| *c.borrow_mut() = None);
        match res {
            Ok(()) => finish_task(&kernel, 0),
            Err(p) => record_panic(&kernel, 0, p),
        }
        // wait for every spawned task to finish (or the run to die)
        let mut st = klock(&kernel);
        while st.violation.is_none()
            && !st.tasks.iter().all(|t| matches!(t, TaskState::Finished))
        {
            st = kernel.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        let decisions = std::mem::take(&mut st.decisions);
        // clone, don't take: parked tasks still unwinding check this
        let violation = st.violation.clone();
        (decisions, violation)
    }
}

/// Explore `f` with default budgets; panics on any violation.
pub fn model<F: Fn()>(f: F) -> Report {
    Checker::default().check(f)
}

/// Drop-in replacements for the `std::sync` types the serve path uses.
/// Outside a model run they behave exactly like the types they wrap.
pub mod shim {
    use super::*;
    use std::ops::{Deref, DerefMut};
    use std::sync::{LockResult, PoisonError};

    /// Per-object registration: (kernel epoch, slot id). Objects that
    /// outlive a run (or are reused across runs) re-register lazily.
    type Slot = OsMutex<Option<(u64, usize)>>;

    macro_rules! shim_atomic {
        ($name:ident, $inner:path, $prim:ty) => {
            /// Model-checked stand-in for the std atomic of the same name.
            pub struct $name {
                inner: $inner,
            }

            impl $name {
                pub const fn new(v: $prim) -> Self {
                    Self { inner: <$inner>::new(v) }
                }

                pub fn load(&self, o: AtomicOrdering) -> $prim {
                    yield_here(concat!(stringify!($name), "::load"));
                    self.inner.load(o)
                }

                pub fn store(&self, v: $prim, o: AtomicOrdering) {
                    yield_here(concat!(stringify!($name), "::store"));
                    self.inner.store(v, o)
                }

                pub fn swap(&self, v: $prim, o: AtomicOrdering) -> $prim {
                    yield_here(concat!(stringify!($name), "::swap"));
                    self.inner.swap(v, o)
                }
            }
        };
    }

    shim_atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool);
    shim_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
    shim_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);

    impl AtomicUsize {
        pub fn fetch_add(&self, v: usize, o: AtomicOrdering) -> usize {
            yield_here("AtomicUsize::fetch_add");
            self.inner.fetch_add(v, o)
        }
    }

    impl AtomicU64 {
        pub fn fetch_add(&self, v: u64, o: AtomicOrdering) -> u64 {
            yield_here("AtomicU64::fetch_add");
            self.inner.fetch_add(v, o)
        }

        pub fn fetch_update<F>(
            &self,
            set: AtomicOrdering,
            fetch: AtomicOrdering,
            f: F,
        ) -> Result<u64, u64>
        where
            F: FnMut(u64) -> Option<u64>,
        {
            yield_here("AtomicU64::fetch_update");
            self.inner.fetch_update(set, fetch, f)
        }
    }

    fn yield_here(label: &str) {
        if let Some((k, me)) = ctx() {
            yield_op(&k, me, label);
        }
    }

    fn register(slot: &Slot, k: &Kernel, condvar: bool) -> usize {
        let mut s = slot.lock().unwrap_or_else(|e| e.into_inner());
        if let Some((epoch, id)) = *s {
            if epoch == k.epoch {
                return id;
            }
        }
        let id = {
            let mut st = klock(k);
            if condvar {
                st.cv_waiters.push(Vec::new());
                st.cv_waiters.len() - 1
            } else {
                st.mutex_owner.push(None);
                st.mutex_owner.len() - 1
            }
        };
        *s = Some((k.epoch, id));
        id
    }

    /// Model-checked stand-in for `std::sync::Mutex`.
    pub struct Mutex<T> {
        inner: OsMutex<T>,
        slot: Slot,
    }

    /// Guard pairing the real lock with the kernel's logical ownership.
    pub struct MutexGuard<'a, T> {
        mx: &'a Mutex<T>,
        inner: Option<OsMutexGuard<'a, T>>,
        model: Option<(Arc<Kernel>, usize)>,
    }

    impl<T> Mutex<T> {
        pub const fn new(t: T) -> Self {
            Self { inner: OsMutex::new(t), slot: OsMutex::new(None) }
        }

        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            if let Some((k, me)) = ctx() {
                yield_op(&k, me, "Mutex::lock");
                let mid = register(&self.slot, &k, false);
                acquire_mutex(&k, me, mid);
                // logical ownership is exclusive, so the real lock is
                // uncontended; poisoning only means an aborted run
                let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
                Ok(MutexGuard { mx: self, inner: Some(g), model: Some((k, mid)) })
            } else {
                match self.inner.lock() {
                    Ok(g) => Ok(MutexGuard { mx: self, inner: Some(g), model: None }),
                    Err(e) => Err(PoisonError::new(MutexGuard {
                        mx: self,
                        inner: Some(e.into_inner()),
                        model: None,
                    })),
                }
            }
        }

        pub fn into_inner(self) -> LockResult<T> {
            match self.inner.into_inner() {
                Ok(t) => Ok(t),
                Err(e) => Err(PoisonError::new(e.into_inner())),
            }
        }
    }

    impl<T> Deref for MutexGuard<'_, T> {
        type Target = T;

        fn deref(&self) -> &T {
            self.inner.as_ref().expect("guard holds the lock")
        }
    }

    impl<T> DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("guard holds the lock")
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            // release the real lock before the logical one so the next
            // logical owner finds it free
            self.inner = None;
            if let Some((k, mid)) = self.model.take() {
                release_mutex(&k, mid);
            }
        }
    }

    /// Model-checked stand-in for `std::sync::Condvar`.
    pub struct Condvar {
        inner: OsCondvar,
        slot: Slot,
    }

    impl Default for Condvar {
        fn default() -> Self {
            Self::new()
        }
    }

    impl Condvar {
        pub const fn new() -> Self {
            Self { inner: OsCondvar::new(), slot: OsMutex::new(None) }
        }

        pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            if let Some((k, mid)) = guard.model.take() {
                let me = ctx().expect("model guard waited outside its run").1;
                let cvid = register(&self.slot, &k, true);
                guard.inner = None;
                let mx = guard.mx;
                drop(guard); // fully disarmed: both halves already released below
                {
                    let mut st = klock(&k);
                    // atomically: release the mutex and park on the condvar
                    st.mutex_owner[mid] = None;
                    wake_mutex_waiters(&mut st, mid);
                    st.tasks[me] = TaskState::BlockedCondvar(cvid);
                    st.cv_waiters[cvid].push(me);
                    pick_locked(&mut st);
                    k.cv.notify_all();
                    loop {
                        if st.violation.is_some() {
                            drop(st);
                            abort_run();
                        }
                        if matches!(st.tasks[me], TaskState::Runnable)
                            && st.current == Some(me)
                        {
                            break;
                        }
                        st = k.cv.wait(st).unwrap_or_else(|e| e.into_inner());
                    }
                }
                mx.lock()
            } else {
                let mx = guard.mx;
                let inner = guard.inner.take().expect("guard holds the lock");
                drop(guard);
                match self.inner.wait(inner) {
                    Ok(g) => Ok(MutexGuard { mx, inner: Some(g), model: None }),
                    Err(e) => Err(PoisonError::new(MutexGuard {
                        mx,
                        inner: Some(e.into_inner()),
                        model: None,
                    })),
                }
            }
        }

        pub fn notify_one(&self) {
            if let Some((k, me)) = ctx() {
                yield_op(&k, me, "Condvar::notify_one");
                let cvid = register(&self.slot, &k, true);
                let mut st = klock(&k);
                if !st.cv_waiters[cvid].is_empty() {
                    // deterministic: always the longest waiter
                    let t = st.cv_waiters[cvid].remove(0);
                    st.tasks[t] = TaskState::Runnable;
                }
                k.cv.notify_all();
            } else {
                self.inner.notify_one();
            }
        }

        pub fn notify_all(&self) {
            if let Some((k, me)) = ctx() {
                yield_op(&k, me, "Condvar::notify_all");
                let cvid = register(&self.slot, &k, true);
                let mut st = klock(&k);
                let waiters = std::mem::take(&mut st.cv_waiters[cvid]);
                for t in waiters {
                    st.tasks[t] = TaskState::Runnable;
                }
                k.cv.notify_all();
            } else {
                self.inner.notify_all();
            }
        }
    }

    /// Model-aware `std::thread` subset: spawned closures become kernel
    /// tasks inside a run and plain threads outside one.
    pub mod thread {
        use super::*;

        pub struct JoinHandle<T> {
            inner: std::thread::JoinHandle<Option<T>>,
            model: Option<(Arc<Kernel>, TaskId)>,
        }

        pub fn spawn<F, T>(f: F) -> JoinHandle<T>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            if let Some((k, me)) = ctx() {
                let id = {
                    let mut st = klock(&k);
                    st.tasks.push(TaskState::Runnable);
                    st.tasks.len() - 1
                };
                let kc = Arc::clone(&k);
                let inner = std::thread::spawn(move || {
                    SUPPRESS_PANIC_OUTPUT.with(|s| s.set(true));
                    CTX.with(|c| *c.borrow_mut() = Some((Arc::clone(&kc), id)));
                    // wait to be scheduled for the first time
                    {
                        let mut st = klock(&kc);
                        loop {
                            if st.violation.is_some() {
                                return None;
                            }
                            if st.current == Some(id)
                                && matches!(st.tasks[id], TaskState::Runnable)
                            {
                                break;
                            }
                            st = kc.cv.wait(st).unwrap_or_else(|e| e.into_inner());
                        }
                    }
                    match catch_unwind(AssertUnwindSafe(f)) {
                        Ok(v) => {
                            finish_task(&kc, id);
                            Some(v)
                        }
                        Err(p) => {
                            record_panic(&kc, id, p);
                            None
                        }
                    }
                });
                // decision point: the child may run before we continue
                yield_op(&k, me, "thread::spawn");
                JoinHandle { inner, model: Some((k, id)) }
            } else {
                JoinHandle { inner: std::thread::spawn(move || Some(f())), model: None }
            }
        }

        impl<T> JoinHandle<T> {
            pub fn join(self) -> std::thread::Result<T> {
                if let Some((k, target)) = self.model {
                    let me = ctx().expect("model JoinHandle joined outside its run").1;
                    loop {
                        {
                            let st = klock(&k);
                            if st.violation.is_some() {
                                drop(st);
                                abort_run();
                            }
                            if matches!(st.tasks[target], TaskState::Finished) {
                                break;
                            }
                        }
                        block_current(&k, me, TaskState::BlockedJoin(target));
                    }
                    match self.inner.join() {
                        Ok(Some(v)) => Ok(v),
                        // the child aborted or panicked: the violation is
                        // already recorded, unwind ourselves out too
                        _ => abort_run(),
                    }
                } else {
                    self.inner.join().map(|v| v.expect("thread returned a value"))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn single_task_model_is_one_schedule() {
        let report = model(|| {
            let a = shim::AtomicUsize::new(0);
            a.store(a.load(Ordering::SeqCst) + 1, Ordering::SeqCst);
            assert_eq!(a.load(Ordering::SeqCst), 1);
        });
        assert_eq!(report.schedules, 1);
        assert!(report.complete);
    }

    #[test]
    fn finds_lost_update_in_racy_increment() {
        let err = Checker::default()
            .try_check(|| {
                let a = Arc::new(shim::AtomicUsize::new(0));
                let t = {
                    let a = Arc::clone(&a);
                    shim::thread::spawn(move || {
                        let v = a.load(Ordering::SeqCst);
                        a.store(v + 1, Ordering::SeqCst);
                    })
                };
                let v = a.load(Ordering::SeqCst);
                a.store(v + 1, Ordering::SeqCst);
                t.join().unwrap();
                assert_eq!(a.load(Ordering::SeqCst), 2, "lost update");
            })
            .expect_err("load;store increments race and must be caught");
        assert!(
            matches!(&err, Violation::TaskPanic { message, .. } if message.contains("lost update")),
            "unexpected violation: {err}"
        );
    }

    #[test]
    fn fetch_add_increment_survives_all_schedules() {
        let report = model(|| {
            let a = Arc::new(shim::AtomicUsize::new(0));
            let t = {
                let a = Arc::clone(&a);
                shim::thread::spawn(move || {
                    a.fetch_add(1, Ordering::SeqCst);
                })
            };
            a.fetch_add(1, Ordering::SeqCst);
            t.join().unwrap();
            assert_eq!(a.load(Ordering::SeqCst), 2);
        });
        assert!(report.complete, "exploration must exhaust within budget");
        assert!(report.schedules > 1, "the race has more than one schedule");
    }

    #[test]
    fn finds_ab_ba_deadlock() {
        let err = Checker::default()
            .try_check(|| {
                let a = Arc::new(shim::Mutex::new(0u32));
                let b = Arc::new(shim::Mutex::new(0u32));
                let t = {
                    let (a, b) = (Arc::clone(&a), Arc::clone(&b));
                    shim::thread::spawn(move || {
                        let _ga = a.lock().unwrap();
                        let _gb = b.lock().unwrap();
                    })
                };
                {
                    let _gb = b.lock().unwrap();
                    let _ga = a.lock().unwrap();
                }
                t.join().unwrap();
            })
            .expect_err("AB-BA lock order must deadlock under some schedule");
        assert!(matches!(err, Violation::Deadlock { .. }), "unexpected violation: {err}");
    }

    #[test]
    fn consistent_lock_order_is_deadlock_free() {
        let report = model(|| {
            let a = Arc::new(shim::Mutex::new(0u32));
            let t = {
                let a = Arc::clone(&a);
                shim::thread::spawn(move || {
                    *a.lock().unwrap() += 1;
                })
            };
            *a.lock().unwrap() += 1;
            t.join().unwrap();
            assert_eq!(*a.lock().unwrap(), 2);
        });
        assert!(report.complete);
    }

    #[test]
    fn finds_missing_notify_deadlock() {
        let err = Checker::default()
            .try_check(|| {
                let pair = Arc::new((shim::Mutex::new(false), shim::Condvar::new()));
                let t = {
                    let pair = Arc::clone(&pair);
                    shim::thread::spawn(move || {
                        // sets the flag but forgets to notify
                        *pair.0.lock().unwrap() = true;
                    })
                };
                {
                    let mut done = pair.0.lock().unwrap();
                    while !*done {
                        done = pair.1.wait(done).unwrap();
                    }
                }
                t.join().unwrap();
            })
            .expect_err("waiting without a notifier must deadlock on some schedule");
        assert!(matches!(err, Violation::Deadlock { .. }), "unexpected violation: {err}");
    }

    #[test]
    fn notify_one_wakes_the_waiter_on_every_schedule() {
        let report = model(|| {
            let pair = Arc::new((shim::Mutex::new(false), shim::Condvar::new()));
            let t = {
                let pair = Arc::clone(&pair);
                shim::thread::spawn(move || {
                    *pair.0.lock().unwrap() = true;
                    pair.1.notify_one();
                })
            };
            {
                let mut done = pair.0.lock().unwrap();
                while !*done {
                    done = pair.1.wait(done).unwrap();
                }
            }
            t.join().unwrap();
        });
        assert!(report.complete);
    }

    #[test]
    fn schedule_budget_reports_incomplete() {
        let checker = Checker { max_schedules: 2, ..Checker::default() };
        let report = checker
            .try_check(|| {
                let a = Arc::new(shim::AtomicUsize::new(0));
                let t = {
                    let a = Arc::clone(&a);
                    shim::thread::spawn(move || {
                        a.fetch_add(1, Ordering::SeqCst);
                    })
                };
                a.fetch_add(1, Ordering::SeqCst);
                t.join().unwrap();
            })
            .expect("correct protocol has no violation");
        assert_eq!(report.schedules, 2);
        assert!(!report.complete, "two schedules cannot exhaust this model");
    }

    #[test]
    fn shims_pass_through_outside_a_model() {
        // no model running: shim types must behave like std types
        let a = Arc::new(shim::AtomicUsize::new(0));
        let m = Arc::new(shim::Mutex::new(0u32));
        let t = {
            let (a, m) = (Arc::clone(&a), Arc::clone(&m));
            shim::thread::spawn(move || {
                a.fetch_add(1, Ordering::SeqCst);
                *m.lock().unwrap() += 1;
            })
        };
        a.fetch_add(1, Ordering::SeqCst);
        *m.lock().unwrap() += 1;
        t.join().unwrap();
        assert_eq!(a.load(Ordering::SeqCst), 2);
        assert_eq!(*m.lock().unwrap(), 2);
    }
}
