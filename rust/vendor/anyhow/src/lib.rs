//! Minimal in-tree stand-in for the `anyhow` crate.
//!
//! The build is fully offline (no registry access), so this vendored
//! crate implements exactly the API surface the workspace uses:
//!
//! * [`Error`] — an error value carrying a context chain,
//! * [`Result<T>`] — `Result<T, Error>` with a defaulted error type,
//! * [`anyhow!`], [`bail!`], [`ensure!`] — ad-hoc error construction,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   (both std errors and `anyhow::Error`) and on `Option`.
//!
//! `Display` prints the outermost message; the alternate form (`{:#}`)
//! prints the whole chain joined with `": "`, matching real anyhow.

use std::fmt;

/// An error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { chain: vec![m.to_string()] }
    }

    fn wrap(mut self, ctx: String) -> Self {
        self.chain.insert(0, ctx);
        self
    }

    /// The context chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message (what `Display` prints).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // flatten the std error's source chain into our message chain
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

// Context attachment works uniformly over std errors and `Error` via a
// helper trait (the same structure real anyhow uses: the blanket impl
// plus a concrete impl for the local `Error`, which never implements
// `std::error::Error`, so the two cannot overlap).
pub trait ChainableError {
    fn ext_context(self, ctx: String) -> Error;
}

impl<E: std::error::Error + Send + Sync + 'static> ChainableError for E {
    fn ext_context(self, ctx: String) -> Error {
        Error::from(self).wrap(ctx)
    }
}

impl ChainableError for Error {
    fn ext_context(self, ctx: String) -> Error {
        self.wrap(ctx)
    }
}

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T, E> {
    fn context<C>(self, ctx: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ChainableError> Context<T, E> for std::result::Result<T, E> {
    fn context<C>(self, ctx: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.ext_context(ctx.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f().to_string()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, ctx: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_outermost_alternate_full_chain() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        let owned = String::from("oops");
        assert_eq!(anyhow!(owned).to_string(), "oops");

        fn fails(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(fails(2).unwrap(), 2);
        assert_eq!(fails(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(fails(11).unwrap_err().to_string(), "x too big: 11");
    }

    #[test]
    fn context_stacks_on_anyhow_results() {
        let e: Error = Err::<(), _>(anyhow!("inner"))
            .context("mid")
            .with_context(|| "outer")
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: mid: inner");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("absent").unwrap_err().to_string(), "absent");
        assert_eq!(Some(4u32).context("absent").unwrap(), 4);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }
}
