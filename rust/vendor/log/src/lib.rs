//! Minimal in-tree stand-in for the `log` facade crate.
//!
//! Implements the subset the workspace uses: the five level macros,
//! [`Level`] / [`LevelFilter`], [`Metadata`] / [`Record`], the [`Log`]
//! trait, and [`set_logger`] / [`set_max_level`]. Semantics match the
//! real facade: levels order `Error < Warn < Info < Debug < Trace`, and
//! a record is emitted when its level is `<=` the configured maximum.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a log record (Error is most severe / lowest).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.pad(s)
    }
}

/// Global maximum level: `Off` disables everything.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

/// Level + target of a potential log record.
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn new(level: Level, target: &'a str) -> Self {
        Self { level, target }
    }

    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message.
#[derive(Clone, Copy, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn new(metadata: Metadata<'a>, args: fmt::Arguments<'a>) -> Self {
        Self { metadata, args }
    }

    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0); // Off until set_max_level

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The configured global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro backend: filter by max level, then dispatch to the logger.
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if (level as usize) > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata::new(level, target);
        if logger.enabled(&metadata) {
            logger.log(&Record::new(metadata, args));
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct CountingLogger;

    impl Log for CountingLogger {
        fn enabled(&self, _: &Metadata) -> bool {
            true
        }
        fn log(&self, record: &Record) {
            assert!(!record.target().is_empty());
            HITS.fetch_add(1, Ordering::Relaxed);
        }
        fn flush(&self) {}
    }

    #[test]
    fn levels_order_and_dispatch() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
        let _ = set_logger(&CountingLogger);
        set_max_level(LevelFilter::Info);
        info!("counted {}", 1);
        debug!("filtered out");
        assert_eq!(max_level(), LevelFilter::Info);
        assert!(HITS.load(Ordering::Relaxed) >= 1);
    }
}
