//! Stub of the `xla` PJRT bindings.
//!
//! The real crate links the PJRT C API and is only available on images
//! with the XLA toolchain installed. This stub carries the exact API
//! surface the workspace uses so every target **compiles** offline;
//! every runtime entry point returns [`Error`] (`PjRtClient::cpu()`
//! fails first, so the deeper calls are unreachable in practice).
//!
//! Swapping in the real bindings is a one-line change in
//! `rust/Cargo.toml` (point the `xla` path dependency at the real
//! crate); no call site changes, since the signatures match.
//!
//! The code paths that need PJRT (AOT artifact execution) already gate
//! on the artifacts directory existing, so `cargo test` stays green on
//! a stub build — the Rust-native attention engines carry all
//! shape-generic compute.

use std::fmt;

/// Error type: everything in the stub fails with this.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "PJRT runtime unavailable: `{what}` called on the stub xla crate \
         (build with the real xla bindings to execute AOT artifacts)"
    ))
}

/// A PJRT client. The stub can never construct one.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }
}

/// A host-side literal (typed dense array).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation ready to compile.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A device-resident buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    // the type parameter mirrors the real bindings' signature; call
    // sites select it by turbofish
    #[allow(clippy::extra_unused_type_parameters)]
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_gracefully() {
        let err = PjRtClient::cpu().err().expect("stub must not construct a client");
        assert!(err.to_string().contains("stub"), "{err}");
    }

    #[test]
    fn literal_roundtrip_paths_error() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
    }
}
