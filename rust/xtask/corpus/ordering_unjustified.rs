// path: rust/src/obs/trace.rs
// expect: atomic-ordering
//
// Seeded violation: a whitelisted module touching an Ordering without
// the adjacent justification comment the lint demands. (Spelling the
// marker out here would land inside the lint's search window.)

use std::sync::atomic::{AtomicBool, Ordering};

static FLAG: AtomicBool = AtomicBool::new(false);

pub fn set() {
    FLAG.store(true, Ordering::SeqCst);
}
