// path: rust/src/obs/bad_metric.rs
// expect: metric-names
//
// Seeded violation: metrics registered under names that never made it
// into docs/OBSERVABILITY.md — one same-line, one rustfmt-wrapped.

use crate::obs::registry::Registry;

pub fn wire(reg: &Registry) {
    reg.counter("corpus_not_documented_total", &[]).inc();
    reg.gauge(
        "corpus_also_missing",
        &[],
    )
    .set(1.0);
}
