// path: rust/src/fault/schedule.rs
// expect: wallclock
//
// Seeded violation: fault/ is deliberately NOT on the wallclock
// whitelist. Injection schedules must be pure in (seed, site, stream,
// tick) so a chaos run replays bit-identically; a schedule that reads
// the wall clock would make every failure unreproducible.

use std::time::Instant;

pub fn fire_now() -> Instant {
    Instant::now()
}
