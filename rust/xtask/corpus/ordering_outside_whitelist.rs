// path: rust/src/attention/bad_atomics.rs
// expect: atomic-ordering
//
// Seeded violation: an attention kernel reaching for raw atomics.
// The whitelist confines Ordering choices to the sync substrate.

use std::sync::atomic::{AtomicUsize, Ordering};

static HITS: AtomicUsize = AtomicUsize::new(0);

pub fn count() {
    HITS.fetch_add(1, Ordering::Relaxed);
}
