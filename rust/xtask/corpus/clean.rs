// path: rust/src/coordinator/batcher.rs
// expect:
//
// The clean control: every idiom below is allowed — `.lock().unwrap()`
// poisoning chains (same-line and rustfmt-split), a justified panic
// site, whitelisted wall-clock use, and a documented metric name. A
// lint firing on any of these is a self-test failure.

use std::sync::Mutex;
use std::time::Instant;

use crate::obs::registry::Registry;

pub fn flush(pending: &Mutex<Vec<u64>>, reg: &Registry) -> usize {
    let opened = Instant::now();
    let drained = pending.lock().unwrap().len();
    let also = pending
        .lock()
        .unwrap()
        .len();
    // lint: allow(serve-panic) — the entry was inserted two lines up
    // in this same function; absence is unreachable.
    let kept = pending.lock().unwrap().first().copied().expect("just checked");
    reg.gauge("batcher_queue_depth", &[]).set(drained as f64);
    let _ = (opened, also, kept);
    drained
}
