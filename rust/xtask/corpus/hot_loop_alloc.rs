// path: rust/src/attention/flash2.rs
// expect: hot-loop
//
// Seeded violation: a per-K-block scratch allocation inside a fenced
// hot loop — exactly the regression the fence exists to catch.

pub fn sweep(n_blocks: usize, bm: usize) -> f32 {
    let mut acc = 0.0f32;
    // hot-loop:begin corpus_sweep
    for _jk in 0..n_blocks {
        let scratch = vec![0.0f32; bm];
        acc += scratch.iter().sum::<f32>();
    }
    // hot-loop:end corpus_sweep
    acc
}
