// path: rust/src/coordinator/bad_panics.rs
// expect: serve-panic
//
// Seeded violation: bare panics on the serve path. Each idiom below
// must be caught; none carries a `lint: allow` justification.

pub fn lookup(map: &std::collections::HashMap<u64, u64>, k: u64) -> u64 {
    let a = map.get(&k).unwrap();
    let b = map.get(&k).expect("present");
    if *a != *b {
        panic!("diverged");
    }
    *a
}
