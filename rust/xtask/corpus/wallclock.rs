// path: rust/src/coordinator/scheduler.rs
// expect: wallclock
//
// Seeded violation: the scheduler reading the wall clock directly.
// Time must flow in as a parameter so pop-order stays simulable.

use std::time::Instant;

pub fn stamp() -> Instant {
    Instant::now()
}
