//! `cargo xtask analyze` — the repo-specific static analysis gate.
//!
//! Rust's generic lints (clippy, rustc) can't see this repo's
//! conventions: which modules are allowed to touch atomic orderings,
//! which loops must stay allocation-free, which JSON layouts are
//! frozen behind schema versions. This binary encodes those rules as
//! line-oriented source lints and runs them over `rust/src`:
//!
//! * `atomic-ordering` — `Ordering::*` only in whitelisted modules,
//!   and every site needs an adjacent `// ordering:` justification.
//! * `wallclock` — `Instant::now` / `SystemTime` only in modules that
//!   legitimately tell time; everything else must take time as input.
//! * `serve-panic` — no `unwrap`/`expect`/`panic!` in serve-path
//!   modules (`coordinator/`, `obs/`) outside `.lock().unwrap()`
//!   poisoning chains or sites carrying `// lint: allow(serve-panic)`.
//! * `hot-loop` — no allocation idioms between `// hot-loop:begin` /
//!   `// hot-loop:end` fences, and the flash2/distr kernels must
//!   keep at least one fence each.
//! * `metric-names` — every metric name registered in `rust/src` must
//!   appear in `docs/OBSERVABILITY.md`.
//! * `schema-stamp` — `// schema:begin <name> v<N>` fenced regions are
//!   content-hashed against `rust/xtask/schema.stamp`; changing a
//!   fenced layout without bumping its version fails the gate.
//!
//! Scanning convention: test modules come last in a file, so each
//! lint only looks at lines before the first top-level `#[cfg(test…)]`
//! marker (schema fences are collected from the whole file).
//!
//! `--self-test` replays every lint against the seeded violation
//! corpus in `rust/xtask/corpus/`; `--update-stamps` rewrites the
//! schema stamp file; `--clippy-args` prints the curated clippy deny
//! set for CI. See `docs/ANALYSIS.md` for the full catalog.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Modules allowed to name an `Ordering` (each site still needs an
/// adjacent `// ordering:` justification).
const ORDERING_WHITELIST: &[&str] = &[
    "rust/src/util/parallel.rs",
    "rust/src/util/testing.rs",
    "rust/src/obs/registry.rs",
    "rust/src/obs/trace.rs",
    "rust/src/obs/probe.rs",
];

/// The model checker forwards `Ordering` values through its shims; the
/// orderings are the callers' choices, so no per-site justification.
const ORDERING_EXEMPT: &[&str] = &["rust/src/util/modelcheck.rs"];

/// Modules that legitimately read wall-clock time.
///
/// `rust/src/fault/` is deliberately ABSENT: fault schedules must be
/// pure functions of `(seed, site, stream, tick)` so a chaos run
/// replays identically — a wall-clock read there is a bug, and the
/// corpus pins the lint to keep firing on it (`wallclock_fault.rs`).
const WALLCLOCK_WHITELIST: &[&str] = &[
    "rust/src/util/bench.rs",
    "rust/src/util/logger.rs",
    "rust/src/util/testing.rs",
    "rust/src/obs/trace.rs",
    "rust/src/autotune/empirical.rs",
    "rust/src/coordinator/batcher.rs",
    "rust/src/coordinator/request.rs",
    "rust/src/coordinator/multi_device.rs",
];
const WALLCLOCK_PREFIX_WHITELIST: &[&str] = &["rust/src/experiments/"];

/// Serve-path modules where a panic kills a request-serving thread.
const SERVE_PANIC_PREFIXES: &[&str] =
    &["rust/src/coordinator/", "rust/src/obs/", "rust/src/serve/"];

/// Files that must keep at least one `// hot-loop:` fence.
const HOT_LOOP_FILES: &[&str] = &[
    "rust/src/attention/flash2.rs",
    "rust/src/attention/distr.rs",
    "rust/src/coordinator/decode.rs",
];

/// Allocation idioms banned inside `// hot-loop:` fences.
const HOT_LOOP_BANNED: &[&str] = &[
    "vec![",
    "Vec::new",
    "::with_capacity",
    ".to_vec(",
    "Box::new(",
    "String::new",
    "format!(",
    ".collect",
    ".clone()",
    ".push(",
    ".resize(",
    ".extend(",
    ".insert(",
    ".to_string(",
];

/// Curated clippy denies CI appends to `cargo clippy -- -D warnings`.
const CLIPPY_DENIES: &[&str] =
    &["clippy::dbg_macro", "clippy::todo", "clippy::unimplemented", "clippy::mem_forget"];

/// How many lines above a flagged site an `// ordering:` or
/// `// lint: allow(...)` comment may sit (rustfmt can split one
/// expression across several lines).
const COMMENT_WINDOW: usize = 8;

struct SourceFile {
    /// Repo-relative path with forward slashes.
    rel: String,
    lines: Vec<String>,
    /// Index of the first top-level test-cfg line; lints stop here.
    code_end: usize,
}

impl SourceFile {
    fn load(root: &Path, rel: String) -> std::io::Result<SourceFile> {
        let text = std::fs::read_to_string(root.join(&rel))?;
        Ok(SourceFile::from_text(rel, &text))
    }

    fn from_text(rel: String, text: &str) -> SourceFile {
        let lines: Vec<String> = text.lines().map(str::to_string).collect();
        let code_end = lines
            .iter()
            .position(|l| l.starts_with("#[cfg(test)]") || l.starts_with("#[cfg(all(test"))
            .unwrap_or(lines.len());
        SourceFile { rel, lines, code_end }
    }

    fn code_lines(&self) -> impl Iterator<Item = (usize, &str)> {
        self.lines[..self.code_end].iter().enumerate().map(|(i, l)| (i + 1, l.as_str()))
    }
}

#[derive(Debug)]
struct Finding {
    lint: &'static str,
    file: String,
    line: usize,
    msg: String,
}

impl Finding {
    fn new(lint: &'static str, file: &str, line: usize, msg: String) -> Finding {
        Finding { lint, file: file.to_string(), line, msg }
    }
}

fn is_comment(line: &str) -> bool {
    line.trim_start().starts_with("//")
}

/// True when `marker` appears on the flagged line or within
/// `COMMENT_WINDOW` comment-bearing lines above it.
fn has_adjacent_marker(file: &SourceFile, idx0: usize, marker: &str) -> bool {
    let lo = idx0.saturating_sub(COMMENT_WINDOW);
    file.lines[lo..=idx0].iter().any(|l| l.contains(marker))
}

// ---------------------------------------------------------------- lints

fn lint_atomic_ordering(file: &SourceFile, out: &mut Vec<Finding>) {
    if ORDERING_EXEMPT.contains(&file.rel.as_str()) {
        return;
    }
    let whitelisted = ORDERING_WHITELIST.contains(&file.rel.as_str());
    for (ln, line) in file.code_lines() {
        if is_comment(line) || line.trim_start().starts_with("use ") {
            continue;
        }
        if !line.contains("Ordering::") {
            continue;
        }
        if !whitelisted {
            out.push(Finding::new(
                "atomic-ordering",
                &file.rel,
                ln,
                "atomic Ordering used outside the whitelisted modules; \
                 route shared state through util::parallel or obs::registry"
                    .to_string(),
            ));
        } else if !has_adjacent_marker(file, ln - 1, "// ordering:") {
            out.push(Finding::new(
                "atomic-ordering",
                &file.rel,
                ln,
                "Ordering site without an adjacent `// ordering:` justification".to_string(),
            ));
        }
    }
}

fn lint_wallclock(file: &SourceFile, out: &mut Vec<Finding>) {
    if WALLCLOCK_WHITELIST.contains(&file.rel.as_str())
        || WALLCLOCK_PREFIX_WHITELIST.iter().any(|p| file.rel.starts_with(p))
    {
        return;
    }
    for (ln, line) in file.code_lines() {
        if is_comment(line) || line.trim_start().starts_with("use ") {
            continue;
        }
        for tok in ["Instant::now", "SystemTime"] {
            if line.contains(tok) {
                out.push(Finding::new(
                    "wallclock",
                    &file.rel,
                    ln,
                    format!(
                        "`{tok}` outside the wallclock whitelist — take time as a \
                         parameter so the logic stays simulable and testable"
                    ),
                ));
            }
        }
    }
}

fn lint_serve_panic(file: &SourceFile, out: &mut Vec<Finding>) {
    if !SERVE_PANIC_PREFIXES.iter().any(|p| file.rel.starts_with(p)) {
        return;
    }
    for (ln, raw) in file.code_lines() {
        if is_comment(raw) {
            continue;
        }
        // `.lock().unwrap()` is the idiomatic poisoning propagation —
        // strip those chains before looking for bare panics.
        let line = raw.replace(".lock().unwrap()", "");
        // rustfmt splits long chains: a lone `.unwrap()` directly under
        // a line ending in `.lock()` is the same idiom.
        let trimmed = line.trim_start();
        if trimmed.starts_with(".unwrap()") {
            let prev = file.lines[..ln - 1]
                .iter()
                .rev()
                .find(|l| !l.trim().is_empty() && !is_comment(l));
            if prev.is_some_and(|p| p.trim_end().ends_with(".lock()")) {
                continue;
            }
        }
        for tok in [".unwrap()", ".expect(", "panic!(", "unreachable!("] {
            if line.contains(tok) {
                if has_adjacent_marker(file, ln - 1, "lint: allow(serve-panic)") {
                    continue;
                }
                out.push(Finding::new(
                    "serve-panic",
                    &file.rel,
                    ln,
                    format!(
                        "`{tok}` in a serve-path module — return an error, or \
                         justify the invariant with `// lint: allow(serve-panic)`"
                    ),
                ));
            }
        }
    }
}

fn lint_hot_loop(file: &SourceFile, out: &mut Vec<Finding>) {
    let mut fence_open: Option<usize> = None;
    let mut fences = 0usize;
    for (ln, line) in file.code_lines() {
        let t = line.trim_start();
        if t.starts_with("// hot-loop:begin") {
            if fence_open.is_some() {
                out.push(Finding::new(
                    "hot-loop",
                    &file.rel,
                    ln,
                    "nested `// hot-loop:begin` — close the previous fence first".to_string(),
                ));
            }
            fence_open = Some(ln);
            fences += 1;
            continue;
        }
        if t.starts_with("// hot-loop:end") {
            if fence_open.is_none() {
                out.push(Finding::new(
                    "hot-loop",
                    &file.rel,
                    ln,
                    "`// hot-loop:end` without a matching begin".to_string(),
                ));
            }
            fence_open = None;
            continue;
        }
        if fence_open.is_some() && !is_comment(line) {
            for tok in HOT_LOOP_BANNED {
                if line.contains(tok) {
                    out.push(Finding::new(
                        "hot-loop",
                        &file.rel,
                        ln,
                        format!("allocation idiom `{tok}` inside a hot-loop fence"),
                    ));
                }
            }
        }
    }
    if let Some(open_ln) = fence_open {
        out.push(Finding::new(
            "hot-loop",
            &file.rel,
            open_ln,
            "unterminated `// hot-loop:begin` fence".to_string(),
        ));
    }
    if fences == 0 && HOT_LOOP_FILES.contains(&file.rel.as_str()) {
        out.push(Finding::new(
            "hot-loop",
            &file.rel,
            1,
            "kernel file lost its `// hot-loop:` fences — the allocation \
             gate no longer covers the inner loop"
                .to_string(),
        ));
    }
}

/// Extract the string literal opening at or after `from` in `line`, or
/// on the following line (rustfmt may wrap the name argument).
fn metric_name_at(file: &SourceFile, idx0: usize, after: usize) -> Option<String> {
    let take = |s: &str| -> Option<String> {
        let rest = s.trim_start();
        let rest = rest.strip_prefix('"')?;
        Some(rest[..rest.find('"')?].to_string())
    };
    let line = &file.lines[idx0][after..];
    take(line).or_else(|| file.lines.get(idx0 + 1).and_then(|l| take(l)))
}

fn lint_metric_names(file: &SourceFile, docs: &str, out: &mut Vec<Finding>) {
    for (ln, line) in file.code_lines() {
        if is_comment(line) {
            continue;
        }
        for method in [".counter(", ".gauge(", ".histogram("] {
            let Some(pos) = line.find(method) else { continue };
            let Some(name) = metric_name_at(file, ln - 1, pos + method.len()) else {
                continue;
            };
            if !docs.contains(&name) {
                out.push(Finding::new(
                    "metric-names",
                    &file.rel,
                    ln,
                    format!("metric `{name}` is not documented in docs/OBSERVABILITY.md"),
                ));
            }
        }
    }
}

// -------------------------------------------------------- schema stamps

#[derive(Debug, Clone)]
struct SchemaFence {
    name: String,
    version: usize,
    /// Optional `const=IDENT` tying the fence version to a Rust const.
    const_ident: Option<String>,
    file: String,
    line: usize,
    hash: u64,
}

fn fnv1a64(lines: &[String]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for line in lines {
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        for b in t.bytes().chain(std::iter::once(b'\n')) {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

fn collect_fences(file: &SourceFile, out: &mut Vec<Finding>) -> Vec<SchemaFence> {
    let mut fences = Vec::new();
    let mut open: Option<(String, usize, Option<String>, usize, Vec<String>)> = None;
    for (i, line) in file.lines.iter().enumerate() {
        let t = line.trim_start();
        if let Some(rest) = t.strip_prefix("// schema:begin ") {
            let mut words = rest.split_whitespace();
            let name = words.next().unwrap_or_default().to_string();
            let version = words
                .next()
                .and_then(|v| v.strip_prefix('v'))
                .and_then(|v| v.parse::<usize>().ok());
            let const_ident = words
                .next()
                .and_then(|w| w.strip_prefix("const="))
                .map(str::to_string);
            let (Some(version), false) = (version, name.is_empty()) else {
                out.push(Finding::new(
                    "schema-stamp",
                    &file.rel,
                    i + 1,
                    "malformed fence; expected `// schema:begin <name> v<N> [const=IDENT]`"
                        .to_string(),
                ));
                continue;
            };
            if open.is_some() {
                out.push(Finding::new(
                    "schema-stamp",
                    &file.rel,
                    i + 1,
                    "schema fence opened inside another fence".to_string(),
                ));
            }
            open = Some((name, version, const_ident, i + 1, Vec::new()));
        } else if let Some(rest) = t.strip_prefix("// schema:end ") {
            match open.take() {
                Some((name, version, const_ident, line, body))
                    if rest.trim() == name =>
                {
                    fences.push(SchemaFence {
                        hash: fnv1a64(&body),
                        name,
                        version,
                        const_ident,
                        file: file.rel.clone(),
                        line,
                    });
                }
                _ => out.push(Finding::new(
                    "schema-stamp",
                    &file.rel,
                    i + 1,
                    format!("`schema:end {}` does not close an open fence", rest.trim()),
                )),
            }
        } else if let Some((_, _, _, _, body)) = open.as_mut() {
            body.push(line.clone());
        }
    }
    if let Some((name, _, _, line, _)) = open {
        out.push(Finding::new(
            "schema-stamp",
            &file.rel,
            line,
            format!("unterminated schema fence `{name}`"),
        ));
    }
    fences
}

/// Check a fence's `const=IDENT` declaration matches its version.
fn check_fence_const(fence: &SchemaFence, file: &SourceFile, out: &mut Vec<Finding>) {
    let Some(ident) = &fence.const_ident else { return };
    let want = format!("const {ident}: usize = {};", fence.version);
    if !file.lines.iter().any(|l| l.contains(&want)) {
        out.push(Finding::new(
            "schema-stamp",
            &fence.file,
            fence.line,
            format!(
                "fence `{}` is v{} but `{want}` was not found — keep the \
                 version const and the fence header in lockstep",
                fence.name, fence.version
            ),
        ));
    }
}

type StampMap = BTreeMap<String, (usize, u64)>;

fn parse_stamps(text: &str) -> StampMap {
    let mut map = StampMap::new();
    for line in text.lines() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut words = t.split_whitespace();
        let (Some(name), Some(ver), Some(hash)) = (words.next(), words.next(), words.next())
        else {
            continue;
        };
        let (Some(ver), Ok(hash)) = (
            ver.strip_prefix('v').and_then(|v| v.parse::<usize>().ok()),
            u64::from_str_radix(hash, 16),
        ) else {
            continue;
        };
        map.insert(name.to_string(), (ver, hash));
    }
    map
}

fn render_stamps(fences: &[SchemaFence]) -> String {
    let mut out = String::from(
        "# Schema stamps — written by `cargo xtask analyze --update-stamps`.\n\
         # <fence-name> v<version> <fnv1a64-of-fenced-lines>\n",
    );
    let mut sorted: Vec<&SchemaFence> = fences.iter().collect();
    sorted.sort_by(|a, b| a.name.cmp(&b.name));
    for f in sorted {
        out.push_str(&format!("{} v{} {:016x}\n", f.name, f.version, f.hash));
    }
    out
}

fn check_stamps(fences: &[SchemaFence], stamps: &StampMap, out: &mut Vec<Finding>) {
    for fence in fences {
        match stamps.get(&fence.name) {
            None => out.push(Finding::new(
                "schema-stamp",
                &fence.file,
                fence.line,
                format!(
                    "fence `{}` has no stamp — run `cargo xtask analyze --update-stamps`",
                    fence.name
                ),
            )),
            Some(&(ver, hash)) => {
                if ver == fence.version && hash != fence.hash {
                    out.push(Finding::new(
                        "schema-stamp",
                        &fence.file,
                        fence.line,
                        format!(
                            "fenced layout `{}` changed without a version bump \
                             (still v{ver}); bump the version, update readers, \
                             then run `cargo xtask analyze --update-stamps`",
                            fence.name
                        ),
                    ));
                } else if ver != fence.version {
                    out.push(Finding::new(
                        "schema-stamp",
                        &fence.file,
                        fence.line,
                        format!(
                            "fence `{}` is v{} but the stamp records v{ver} — \
                             run `cargo xtask analyze --update-stamps`",
                            fence.name, fence.version
                        ),
                    ));
                }
            }
        }
    }
    for name in stamps.keys() {
        if !fences.iter().any(|f| &f.name == name) {
            out.push(Finding::new(
                "schema-stamp",
                "rust/xtask/schema.stamp",
                1,
                format!("stale stamp `{name}`: no such fence in the tree"),
            ));
        }
    }
}

// ------------------------------------------------------------- drivers

fn rust_sources(root: &Path) -> std::io::Result<Vec<String>> {
    let mut rels = Vec::new();
    let mut stack = vec![root.join("rust/src")];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path
                    .strip_prefix(root)
                    .expect("walked paths live under the repo root")
                    .to_string_lossy()
                    .replace('\\', "/");
                rels.push(rel);
            }
        }
    }
    rels.sort();
    Ok(rels)
}

fn run_content_lints(file: &SourceFile, docs: &str, out: &mut Vec<Finding>) {
    lint_atomic_ordering(file, out);
    lint_wallclock(file, out);
    lint_serve_panic(file, out);
    lint_hot_loop(file, out);
    lint_metric_names(file, docs, out);
}

fn analyze(root: &Path, update_stamps: bool) -> Result<usize, String> {
    let docs = std::fs::read_to_string(root.join("docs/OBSERVABILITY.md"))
        .map_err(|e| format!("docs/OBSERVABILITY.md: {e}"))?;
    let mut findings = Vec::new();
    let mut fences = Vec::new();
    let rels = rust_sources(root).map_err(|e| format!("walking rust/src: {e}"))?;
    let n_files = rels.len();
    for rel in rels {
        let file = SourceFile::load(root, rel).map_err(|e| format!("read: {e}"))?;
        run_content_lints(&file, &docs, &mut findings);
        for fence in collect_fences(&file, &mut findings) {
            check_fence_const(&fence, &file, &mut findings);
            fences.push(fence);
        }
    }

    let stamp_path = root.join("rust/xtask/schema.stamp");
    let stamps = match std::fs::read_to_string(&stamp_path) {
        Ok(text) => parse_stamps(&text),
        Err(_) => StampMap::new(),
    };
    if update_stamps {
        // a same-version content change still has to fail: stamping over
        // it would defeat the gate
        let mut bump_errors = Vec::new();
        for fence in &fences {
            if let Some(&(ver, hash)) = stamps.get(&fence.name) {
                if ver == fence.version && hash != fence.hash {
                    bump_errors.push(format!(
                        "{}:{}: `{}` changed but is still v{ver} — bump the version first",
                        fence.file, fence.line, fence.name
                    ));
                }
            }
        }
        if !bump_errors.is_empty() {
            return Err(bump_errors.join("\n"));
        }
        std::fs::write(&stamp_path, render_stamps(&fences))
            .map_err(|e| format!("writing {}: {e}", stamp_path.display()))?;
        println!("analyze: stamped {} schema fence(s)", fences.len());
    } else {
        check_stamps(&fences, &stamps, &mut findings);
    }

    if findings.is_empty() {
        println!(
            "analyze: {n_files} files clean, {} schema fence(s) verified",
            fences.len()
        );
        Ok(0)
    } else {
        findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
        for f in &findings {
            eprintln!("{}:{}: [{}] {}", f.file, f.line, f.lint, f.msg);
        }
        Ok(findings.len())
    }
}

// ------------------------------------------------------------ self-test

/// Replay the lints against the seeded corpus: every file declares the
/// virtual path it pretends to live at and the exact set of lints that
/// must fire on it. A lint that stays silent on its seeded violation —
/// or fires on the clean file — fails the self-test.
fn self_test(root: &Path) -> Result<(), String> {
    let docs = std::fs::read_to_string(root.join("docs/OBSERVABILITY.md"))
        .map_err(|e| format!("docs/OBSERVABILITY.md: {e}"))?;
    let corpus = root.join("rust/xtask/corpus");
    let mut errors = Vec::new();
    let mut cases = 0usize;

    let mut entries: Vec<PathBuf> = std::fs::read_dir(&corpus)
        .map_err(|e| format!("{}: {e}", corpus.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "rs"))
        .collect();
    entries.sort();

    for path in &entries {
        cases += 1;
        let text = std::fs::read_to_string(path).map_err(|e| format!("{e}"))?;
        let mut virt = String::new();
        let mut expect: Vec<String> = Vec::new();
        for line in text.lines() {
            if let Some(p) = line.strip_prefix("// path: ") {
                virt = p.trim().to_string();
            } else if let Some(l) = line.strip_prefix("// expect: ") {
                expect.push(l.trim().to_string());
            }
        }
        expect.sort();
        let file = SourceFile::from_text(virt.clone(), &text);
        let mut findings = Vec::new();
        run_content_lints(&file, &docs, &mut findings);
        let mut fired: Vec<String> =
            findings.iter().map(|f| f.lint.to_string()).collect();
        fired.sort();
        fired.dedup();
        if fired != expect {
            errors.push(format!(
                "{}: expected lints {expect:?}, got {fired:?}",
                path.display()
            ));
        }
    }

    // schema-stamp scenarios run against the fence corpus explicitly,
    // since they need a stamp map to compare with.
    let fence_path = corpus.join("schema_fence.fixture");
    let text =
        std::fs::read_to_string(&fence_path).map_err(|e| format!("schema fixture: {e}"))?;
    let file = SourceFile::from_text("rust/src/util/fixture.rs".to_string(), &text);
    let mut parse_errors = Vec::new();
    let fences = collect_fences(&file, &mut parse_errors);
    if !parse_errors.is_empty() || fences.len() != 1 {
        errors.push(format!(
            "schema fixture must parse to exactly one fence (got {}, {} parse errors)",
            fences.len(),
            parse_errors.len()
        ));
    } else {
        let fence = &fences[0];
        cases += 3;
        // 1) missing stamp must fire
        let mut f = Vec::new();
        check_stamps(&fences, &StampMap::new(), &mut f);
        if f.len() != 1 {
            errors.push("schema-stamp: missing stamp did not fire".to_string());
        }
        // 2) same version, wrong hash must fire
        let mut stale = StampMap::new();
        stale.insert(fence.name.clone(), (fence.version, fence.hash ^ 1));
        let mut f = Vec::new();
        check_stamps(&fences, &stale, &mut f);
        if !f.iter().any(|f| f.msg.contains("without a version bump")) {
            errors.push("schema-stamp: silent layout change did not fire".to_string());
        }
        // 3) matching stamp must stay silent
        let mut good = StampMap::new();
        good.insert(fence.name.clone(), (fence.version, fence.hash));
        let mut f = Vec::new();
        check_stamps(&fences, &good, &mut f);
        if !f.is_empty() {
            errors.push("schema-stamp: clean fence fired".to_string());
        }
        // 4) const=IDENT disagreement must fire
        cases += 1;
        let bad = SchemaFence {
            version: fence.version + 1,
            ..fence.clone()
        };
        let mut f = Vec::new();
        check_fence_const(&bad, &file, &mut f);
        if f.len() != 1 {
            errors.push("schema-stamp: version-const mismatch did not fire".to_string());
        }
    }

    if errors.is_empty() {
        println!("analyze --self-test: {cases} corpus cases passed");
        Ok(())
    } else {
        Err(errors.join("\n"))
    }
}

fn repo_root() -> PathBuf {
    // rust/xtask/ -> rust/ -> repo root
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(2)
        .expect("xtask lives two levels under the repo root")
        .to_path_buf()
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str);
    if cmd != Some("analyze") {
        eprintln!(
            "usage: cargo xtask analyze [--self-test | --update-stamps | --clippy-args]"
        );
        return ExitCode::from(2);
    }
    if args.iter().any(|a| a == "--clippy-args") {
        let flags: Vec<String> =
            CLIPPY_DENIES.iter().map(|d| format!("-D {d}")).collect();
        println!("{}", flags.join(" "));
        return ExitCode::SUCCESS;
    }
    let root = repo_root();
    if args.iter().any(|a| a == "--self-test") {
        return match self_test(&root) {
            Ok(()) => ExitCode::SUCCESS,
            Err(e) => {
                eprintln!("analyze --self-test FAILED:\n{e}");
                ExitCode::FAILURE
            }
        };
    }
    let update = args.iter().any(|a| a == "--update-stamps");
    match analyze(&root, update) {
        Ok(0) => ExitCode::SUCCESS,
        Ok(n) => {
            eprintln!("analyze: {n} finding(s)");
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("analyze: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(rel: &str, text: &str) -> SourceFile {
        SourceFile::from_text(rel.to_string(), text)
    }

    #[test]
    fn test_cfg_truncates_scanning() {
        let f = file(
            "rust/src/coordinator/x.rs",
            "fn a() {}\n#[cfg(test)]\nmod tests { fn b() { x.unwrap(); } }\n",
        );
        let mut out = Vec::new();
        lint_serve_panic(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn lock_unwrap_chains_are_exempt() {
        let src = "fn a() {\n    m.lock().unwrap().push(1);\n    m\n        .lock()\n        .unwrap()\n        .len();\n}\n";
        let f = file("rust/src/obs/x.rs", src);
        let mut out = Vec::new();
        lint_serve_panic(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn bare_unwrap_fires_and_allow_silences() {
        let f = file("rust/src/coordinator/x.rs", "fn a() { v.unwrap(); }\n");
        let mut out = Vec::new();
        lint_serve_panic(&f, &mut out);
        assert_eq!(out.len(), 1);
        let f = file(
            "rust/src/coordinator/x.rs",
            "// lint: allow(serve-panic) — invariant\nfn a() { v.unwrap(); }\n",
        );
        let mut out = Vec::new();
        lint_serve_panic(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn fnv_matches_reference_vector() {
        // FNV-1a 64 of "a\n" (one trimmed line + newline)
        let h = fnv1a64(&["a".to_string()]);
        let mut want: u64 = 0xcbf29ce484222325;
        for b in [b'a', b'\n'] {
            want ^= u64::from(b);
            want = want.wrapping_mul(0x100000001b3);
        }
        assert_eq!(h, want);
        // indentation and blank lines do not affect the hash
        assert_eq!(
            fnv1a64(&["  a".to_string(), String::new()]),
            fnv1a64(&["a".to_string()])
        );
    }

    #[test]
    fn stamp_roundtrip() {
        let fences = vec![SchemaFence {
            name: "x".into(),
            version: 2,
            const_ident: None,
            file: "f.rs".into(),
            line: 1,
            hash: 0xdeadbeef,
        }];
        let text = render_stamps(&fences);
        let map = parse_stamps(&text);
        assert_eq!(map.get("x"), Some(&(2, 0xdeadbeef)));
    }
}
