"""AOT compilation: lower every Layer-2 entry point to HLO **text** and
emit a manifest the Rust runtime consumes. Build-time only — after
``make artifacts`` the Rust binary is self-contained.

Interchange format is HLO text, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (DESIGN.md §6):
  * single-head attention microkernels (exact / flash / distr) — the
    quickstart, runtime tests and PJRT cross-checks,
  * multi-head chunk kernels — the device-pool scatter path (Table 9),
  * LM prefill at several sequence lengths/variants — serve_llm + TTFT,
  * the LM train step — the end-to-end training driver,
  * ViT forward (exact + distr) — vit_inference / Table 8.

Model parameters are artifact *inputs* (not folded constants) and are
exported once to ``<name>.params.bin`` + ``.params.json`` so Rust can
load, feed, and (for the train step) round-trip them.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, train
from .attention_api import AttentionConfig
from .kernels import distr, flash, ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides literals as
    # `constant({...})`, which the text parser happily reads back as
    # ZEROS — silently corrupting e.g. the LSH projection matrix.
    return comp.as_hlo_text(True)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[np.dtype(dt).name]


class ArtifactWriter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest: dict = {"format": 1, "artifacts": {}}
        os.makedirs(out_dir, exist_ok=True)
        # partial rebuilds (--only ...) merge into the existing manifest
        existing = os.path.join(out_dir, "manifest.json")
        if os.path.exists(existing):
            with open(existing) as f:
                prev = json.load(f)
            if prev.get("format") == 1:
                self.manifest["artifacts"].update(prev.get("artifacts", {}))

    def add(self, name: str, fn, in_specs: list, meta: dict | None = None, params_export=None):
        """Lower ``fn(*in_specs)`` to HLO text and register it.

        ``params_export``: optional pytree whose flattened leaves are the
        leading inputs; exported to a sidecar .bin/.json pair.
        """
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_tree = jax.eval_shape(fn, *in_specs)
        entry = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(s.shape), "dtype": _dtype_name(s.dtype)} for s in in_specs
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": _dtype_name(o.dtype)}
                for o in jax.tree.leaves(out_tree)
            ],
            "meta": meta or {},
        }
        if params_export is not None:
            entry["params"] = self._export_params(name, params_export)
        self.manifest["artifacts"][name] = entry
        print(f"  [{time.time()-t0:6.1f}s] {name}: {len(text)/1e6:.2f} MB HLO text")

    def _export_params(self, name: str, pytree) -> dict:
        leaves_with_paths = jax.tree_util.tree_flatten_with_path(pytree)[0]
        index, blobs, offset = [], [], 0
        for path, leaf in leaves_with_paths:
            arr = np.asarray(leaf, dtype=np.float32)
            index.append(
                {
                    "name": jax.tree_util.keystr(path),
                    "shape": list(arr.shape),
                    "offset": offset,
                    "numel": int(arr.size),
                }
            )
            blobs.append(arr.tobytes())
            offset += arr.size * 4
        bin_name, json_name = f"{name}.params.bin", f"{name}.params.json"
        with open(os.path.join(self.out_dir, bin_name), "wb") as f:
            f.write(b"".join(blobs))
        with open(os.path.join(self.out_dir, json_name), "w") as f:
            json.dump({"leaves": index, "total_bytes": offset}, f, indent=1)
        return {"bin": bin_name, "index": json_name, "n_leaves": len(index)}

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"wrote {len(self.manifest['artifacts'])} artifacts to {self.out_dir}")


# ---------------------------------------------------------------------------
# artifact definitions
# ---------------------------------------------------------------------------


def add_attention_micro(w: ArtifactWriter):
    """Single-head attention microkernels for the runtime + quickstart."""
    for n, d in [(256, 64), (512, 64), (256, 128)]:
        s = [spec((n, d))] * 3
        w.add(
            f"attn_exact_{n}x{d}",
            lambda q, k, v: (ref.exact_attention(q, k, v),),
            s,
            meta={"kind": "attention", "variant": "standard", "n": n, "d": d},
        )
        w.add(
            f"attn_flash_{n}x{d}",
            lambda q, k, v: (flash.flash_attention(q, k, v, block_l=16, block_m=16),),
            s,
            meta={"kind": "attention", "variant": "flash", "n": n, "d": d,
                  "block_l": 16, "block_m": 16},
        )
        for g in (2, 4):
            w.add(
                f"attn_distr_{n}x{d}_g{g}",
                lambda q, k, v, g=g: (
                    distr.distr_attention(q, k, v, block_l=16, block_m=16, group=g),
                ),
                s,
                meta={"kind": "attention", "variant": "distr_flash", "n": n, "d": d,
                      "block_l": 16, "block_m": 16, "group": g},
            )


def add_multihead_chunk(w: ArtifactWriter):
    """Head-chunk kernels for the multi-device scatter bench (Table 9)."""
    h, n, d = 4, 1024, 128
    s = [spec((h, n, d))] * 3
    mh = ref.multihead

    w.add(
        f"attn_mh{h}_{n}x{d}_flash",
        lambda q, k, v: (mh(lambda a, b, c: flash.flash_attention(a, b, c, 16, 16))(q, k, v),),
        s,
        meta={"kind": "attention_mh", "variant": "flash", "h": h, "n": n, "d": d},
    )
    w.add(
        f"attn_mh{h}_{n}x{d}_distr",
        lambda q, k, v: (
            mh(lambda a, b, c: distr.distr_attention(a, b, c, 16, 16, group=2))(q, k, v),
        ),
        s,
        meta={"kind": "attention_mh", "variant": "distr_flash", "h": h, "n": n, "d": d,
              "group": 2},
    )


LM_CFG = model.LMConfig(vocab=512, d_model=256, n_heads=4, n_layers=4, d_ff=512)
VIT_CFG = model.ViTConfig()


def add_lm(w: ArtifactWriter):
    params = model.lm_init(LM_CFG, seed=0)
    flat = jax.tree.leaves(params)
    treedef = jax.tree.structure(params)
    param_specs = [spec(p.shape) for p in flat]

    for variant in ("standard", "flash", "distr_flash"):
        acfg = AttentionConfig(variant=variant, block_l=16, block_m=16, group=2)
        for n in (128, 256):
            def fwd(*args, acfg=acfg, n=n):
                ps, toks = args[:-1], args[-1]
                p = jax.tree.unflatten(treedef, ps)
                return (model.lm_forward(p, toks, LM_CFG, acfg),)

            w.add(
                f"lm_prefill_{variant}_{n}",
                fwd,
                param_specs + [spec((1, n), jnp.int32)],
                meta={"kind": "lm_prefill", "variant": variant, "n": n,
                      "vocab": LM_CFG.vocab, "d_model": LM_CFG.d_model,
                      "n_layers": LM_CFG.n_layers, "n_heads": LM_CFG.n_heads},
                params_export=params if variant == "standard" and n == 128 else None,
            )


def add_lm_train(w: ArtifactWriter):
    params = model.lm_init(LM_CFG, seed=0)
    opt = train.adamw_init(params)
    acfg = AttentionConfig(variant="distr_flash", block_l=16, block_m=16, group=2,
                           trainable=True)
    step = train.make_lm_train_step(LM_CFG, acfg, lr=3e-4)
    b, n = 4, 128

    p_tree = jax.tree.structure(params)
    o_tree = jax.tree.structure(opt)
    p_flat = jax.tree.leaves(params)
    o_flat = jax.tree.leaves(opt)

    def step_flat(*args):
        np_, no_ = len(p_flat), len(o_flat)
        ps = jax.tree.unflatten(p_tree, args[:np_])
        os_ = jax.tree.unflatten(o_tree, args[np_: np_ + no_])
        toks, tgts = args[np_ + no_], args[np_ + no_ + 1]
        new_p, new_o, loss = step(ps, os_, toks, tgts)
        return tuple(jax.tree.leaves(new_p)) + tuple(jax.tree.leaves(new_o)) + (loss,)

    in_specs = (
        [spec(p.shape) for p in p_flat]
        + [spec(o.shape) for o in o_flat]
        + [spec((b, n), jnp.int32), spec((b, n), jnp.int32)]
    )
    w.add(
        "lm_train_step",
        step_flat,
        in_specs,
        meta={"kind": "lm_train", "variant": "distr_flash", "batch": b, "n": n,
              "n_params": len(p_flat), "n_opt": len(o_flat), "vocab": LM_CFG.vocab,
              "lr": 3e-4},
        # a TUPLE, not a dict: tree_flatten sorts dict keys, which would
        # reorder the blob's leaves away from the executable's input order
        params_export=(params, opt),
    )


def add_vit(w: ArtifactWriter):
    params = model.vit_init(VIT_CFG, seed=0)
    flat = jax.tree.leaves(params)
    treedef = jax.tree.structure(params)
    param_specs = [spec(p.shape) for p in flat]
    b = 8

    for variant in ("standard", "distr_flash"):
        acfg = AttentionConfig(variant=variant, block_l=16, block_m=16, group=2)

        def fwd(*args, acfg=acfg):
            ps, imgs = args[:-1], args[-1]
            p = jax.tree.unflatten(treedef, ps)
            return (model.vit_forward(p, imgs, VIT_CFG, acfg),)

        w.add(
            f"vit_fwd_{variant}_b{b}",
            fwd,
            param_specs + [spec((b, VIT_CFG.image_size, VIT_CFG.image_size, VIT_CFG.channels))],
            meta={"kind": "vit_fwd", "variant": variant, "batch": b,
                  "n_classes": VIT_CFG.n_classes, "image_size": VIT_CFG.image_size},
            params_export=params if variant == "standard" else None,
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None, help="comma list: micro,mh,lm,train,vit")
    args = ap.parse_args()
    sel = set(args.only.split(",")) if args.only else None
    w = ArtifactWriter(args.out)
    if sel is None or "micro" in sel:
        add_attention_micro(w)
    if sel is None or "mh" in sel:
        add_multihead_chunk(w)
    if sel is None or "lm" in sel:
        add_lm(w)
    if sel is None or "train" in sel:
        add_lm_train(w)
    if sel is None or "vit" in sel:
        add_vit(w)
    w.finish()


if __name__ == "__main__":
    main()
