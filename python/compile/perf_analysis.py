"""L1/L2 performance analysis (EXPERIMENTS.md §Perf).

interpret=True gives numpy-backed timings, NOT a TPU proxy, so Layer-1
performance is assessed *structurally*:

* VMEM footprint per BlockSpec configuration — does the working set fit
  the ~16 MiB/core budget, and how much headroom does DistrAttention's
  d/G* shrink buy?
* MXU utilization estimate — fraction of each (128×128 systolic) pass
  that carries real data, for the score matmul tiles of flash2 vs distr.
* the roofline-style FLOP/byte ratio per schedule.

Layer-2 is audited on the lowered HLO text: op histogram per artifact,
checking for duplicated softmax work and counting fusion-relevant ops.

Run from python/:  python -m compile.perf_analysis
"""

from __future__ import annotations

import os
import re
import sys

VMEM_BYTES = 16 * 1024 * 1024          # per-core VMEM on current TPUs
MXU = 128                               # systolic tile edge
BF16 = 2


def vmem_footprint(l: int, m: int, n_kv: int, d: int, group: int = 1) -> dict:
    """Bytes resident per grid step of the (distr-)flash kernel.

    The kernel holds: one Q block (l×d), the full K and V (streamed
    blocks of m rows are slices of resident buffers under interpret;
    on real TPU BlockSpec would stream K/V in m-row blocks, so both
    figures are reported), the sampled Q (l×d/G*), the fused K block
    (m×d/G*), the S tile (l×m) and the O accumulator (l×d).
    """
    dg = d // group
    resident_stream = (
        l * d            # Q block
        + 2 * m * d      # K,V blocks (streamed)
        + l * dg         # sampled Q
        + m * dg         # fused K
        + l * m          # S tile
        + l * d          # O accumulator + (m,l) stats ~ l*2
        + 2 * l
    ) * BF16 * 2         # fp32 accumulation: x2 over bf16 storage
    resident_full_kv = resident_stream + 2 * (n_kv - m) * d * BF16
    return {"stream": resident_stream, "full_kv": resident_full_kv}


def mxu_utilization(rows: int, cols: int, contraction: int) -> float:
    """Fraction of MXU capacity used by a rows×contraction @ contraction×cols
    matmul when tiles are padded up to 128."""
    pad = lambda x: ((x + MXU - 1) // MXU) * MXU
    useful = rows * cols * contraction
    padded = pad(rows) * pad(cols) * pad(contraction)
    return useful / padded


def analyze_kernels() -> str:
    lines = [
        "### L1 — Pallas kernel structural analysis",
        "",
        "VMEM per grid step (bf16 storage, fp32 accum; 'stream' = BlockSpec",
        "streams K/V m-row blocks as on TPU; budget 16 MiB/core):",
        "",
        "| schedule | l | m | d | G* | VMEM/step | % budget | score-MXU util | flop/byte |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    n_kv = 2048
    configs = [
        ("flash2", 128, 128, 64, 1),
        ("flash2", 128, 32, 128, 1),
        ("distr", 128, 128, 64, 2),
        ("distr", 128, 128, 64, 4),
        ("distr", 128, 32, 128, 2),
        ("distr", 256, 64, 32, 2),
    ]
    for name, l, m, d, g in configs:
        fp = vmem_footprint(l, m, n_kv, d, g)
        dg = d // g
        util = mxu_utilization(l, m, dg)
        # flops per step: scores 2*l*m*dg + pv 2*l*m*d; bytes: q,k,v blocks
        flops = 2 * l * m * dg + 2 * l * m * d
        bytes_moved = (l * d + 2 * m * d) * BF16
        lines.append(
            f"| {name} | {l} | {m} | {d} | {g} | {fp['stream']/1024:.0f} KiB "
            f"| {fp['stream']/VMEM_BYTES*100:.1f}% | {util*100:.0f}% "
            f"| {flops/bytes_moved:.1f} |"
        )
    lines += [
        "",
        "Reading: DistrAttention shrinks the score contraction to d/G*, which",
        "(a) cuts the per-step score FLOPs by (1-1/G*)/2 of the total, and",
        "(b) keeps the MXU tile fully utilized as long as d/G* >= 128 is not",
        "required — at d/G* < 128 the contraction dim under-fills one MXU pass",
        "(64 -> 50%, 32 -> 25%), which is exactly the paper's tensor-core",
        "constraint: G*=4 is skipped at d=32 (d/G*=8 << N'=16).",
        "The VMEM saving from the fused K block lets (l, m) grow one notch",
        "within the same budget — the paper's Table 2 selection lever.",
    ]
    return "\n".join(lines)


HLO_OPS = ["dot", "exponential", "reduce", "while", "gather", "sort", "divide",
           "dynamic-slice", "dynamic-update-slice", "broadcast"]


def audit_hlo(path: str) -> dict:
    text = open(path).read()
    counts = {}
    for op in HLO_OPS:
        counts[op] = len(re.findall(rf"= [a-z0-9\[\],{{}}: ]* {re.escape(op)}\(", text)) or \
                     len(re.findall(rf"\b{re.escape(op)}\(", text))
    counts["bytes"] = len(text)
    return counts


def analyze_artifacts(art_dir: str) -> str:
    lines = [
        "### L2 — HLO audit of lowered artifacts",
        "",
        "| artifact | dots | exp | reduce | while | sort | gather | size |",
        "|---|---|---|---|---|---|---|---|",
    ]
    targets = [
        "attn_exact_256x64", "attn_flash_256x64", "attn_distr_256x64_g2",
        "lm_prefill_distr_flash_128", "lm_train_step",
    ]
    for name in targets:
        p = os.path.join(art_dir, f"{name}.hlo.txt")
        if not os.path.exists(p):
            continue
        c = audit_hlo(p)
        lines.append(
            f"| {name} | {c['dot']} | {c['exponential']} | {c['reduce']} "
            f"| {c['while']} | {c['sort']} | {c['gather']} | {c['bytes']//1024} KiB |"
        )
    lines += [
        "",
        "Checks: one `exponential` cluster per softmax (no duplicated",
        "normalization); `sort` appears once per LSH grouping; the Pallas",
        "kernels lower to a single `while` (grid loop) rather than unrolled",
        "bodies, keeping executable size flat in N.",
    ]
    return "\n".join(lines)


def main():
    art = sys.argv[1] if len(sys.argv) > 1 else "../artifacts"
    print(analyze_kernels())
    print()
    print(analyze_artifacts(art))


if __name__ == "__main__":
    main()
