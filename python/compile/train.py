"""Losses, optimizers and AOT-able train steps for the fine-tuning
experiments (paper §4.3/§4.4) and the end-to-end training driver.

The train step is a pure function ``(params, opt_state, batch) ->
(params', opt_state', loss)`` so it lowers to a single HLO module the
Rust runtime executes in a loop, feeding the updated parameter literals
back in (examples/train_e2e.rs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model
from .attention_api import AttentionConfig


def cross_entropy_lm(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token cross entropy. logits (B, N, V), targets (B, N)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -picked.mean()


def cross_entropy_cls(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


# ---------------------------------------------------------------------------
# optimizers (plain pytree, no optax: keeps the AOT module dependency-free)
# ---------------------------------------------------------------------------


def sgd_init(params):
    return jax.tree.map(jnp.zeros_like, params)  # momentum buffers


def sgd_update(params, grads, momentum, lr=0.05, beta=0.9):
    new_m = jax.tree.map(lambda m, g: beta * m + g, momentum, grads)
    new_p = jax.tree.map(lambda p, m: p - lr * m, params, new_m)
    return new_p, new_m


def adamw_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.float32)}


def adamw_update(params, grads, state, lr=3e-4, b1=0.9, b2=0.999, eps=1e-8, wd=0.01):
    t = state["t"] + 1.0
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda x: x / (1 - b1**t), m)
    vh = jax.tree.map(lambda x: x / (1 - b2**t), v)
    new_p = jax.tree.map(
        lambda p, mh_, vh_: p - lr * (mh_ / (jnp.sqrt(vh_) + eps) + wd * p), params, mh, vh
    )
    return new_p, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# train steps
# ---------------------------------------------------------------------------


def make_lm_train_step(cfg: model.LMConfig, attn_cfg: AttentionConfig, lr: float = 3e-4):
    """AdamW LM train step. batch = (tokens (B,N), targets (B,N))."""

    def loss_fn(params, tokens, targets):
        logits = model.lm_forward(params, tokens, cfg, attn_cfg)
        return cross_entropy_lm(logits, targets)

    def step(params, opt_state, tokens, targets):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, targets)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
        return params, opt_state, loss

    return step


def make_vit_train_step(cfg: model.ViTConfig, attn_cfg: AttentionConfig, lr: float = 1e-3):
    """AdamW classifier train step. batch = (images (B,H,W,C), labels (B,))."""

    def loss_fn(params, images, labels):
        logits = model.vit_forward(params, images, cfg, attn_cfg)
        return cross_entropy_cls(logits, labels)

    def step(params, opt_state, images, labels):
        loss, grads = jax.value_and_grad(loss_fn)(params, images, labels)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
        return params, opt_state, loss

    return step
