"""Uniform attention dispatch: every mechanism the paper evaluates
behind one per-head signature ``fn(q, k, v) -> o`` with shapes (N, d).

This is what makes DistrAttention "flexible" in the paper's sense: the
variant (and its speed/accuracy trade-off knobs G*, l, m) is a config
value, not an architecture change — output shapes, token count and
positions are untouched, so any pre-trained checkpoint can swap
mechanisms (paper §4.3, §4.6).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax.numpy as jnp

from .kernels import baselines, distr, flash, ref


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    """The paper's tunables plus our implementation toggles.

    variant: one of VARIANTS below.
    block_l / block_m: FlashAttention-2 Q / K+V block sizes (paper l, m).
    group: the sampling rate G* (columns fused per group).
    sample: 'mean' (default; matches the paper's error bands) or
            'first' (the paper's literal single-column sampling).
    center: center columns before LSH projection (DESIGN.md §5 S2).
    trainable: use the custom-vjp wrapper so fwd runs the Pallas kernel.
    """

    variant: str = "distr_flash"
    block_l: int = 16
    block_m: int = 16
    group: int = 2
    sample: str = "mean"
    center: bool = True
    seed: int = 0
    trainable: bool = False


VARIANTS = (
    "standard",      # exact softmax attention (Attn-Standard)
    "flash",         # exact, FlashAttention-2 Pallas kernel (Flash2)
    "distr",         # DistrAttention, jnp reference pipeline (Ours)
    "distr_flash",   # DistrAttention fused Pallas kernel (Ours-Flash)
    "hydra",
    "hyper",
    "flatten",
    "primal",
    "linformer",
)


def make_attention(cfg: AttentionConfig, causal: bool = False) -> Callable:
    """Build the per-head attention callable for ``cfg``."""
    v = cfg.variant
    if v == "standard":
        return functools.partial(ref.exact_attention, causal=causal)
    if v == "flash":
        return functools.partial(
            flash.flash_attention, block_l=cfg.block_l, block_m=cfg.block_m, causal=causal
        )
    if v == "distr":
        return functools.partial(
            ref.distr_attention_ref,
            block_l=cfg.block_l,
            block_m=cfg.block_m,
            group=cfg.group,
            sample=cfg.sample,
            causal=causal,
            seed=cfg.seed,
            center=cfg.center,
        )
    if v == "distr_flash":
        if cfg.trainable:
            return distr.make_distr_attention_vjp(
                block_l=cfg.block_l,
                block_m=cfg.block_m,
                group=cfg.group,
                causal=causal,
                sample=cfg.sample,
                seed=cfg.seed,
                center=cfg.center,
            )
        return functools.partial(
            distr.distr_attention,
            block_l=cfg.block_l,
            block_m=cfg.block_m,
            group=cfg.group,
            causal=causal,
            sample=cfg.sample,
            seed=cfg.seed,
            center=cfg.center,
        )
    if v == "hydra":
        return functools.partial(baselines.hydra_attention, causal=causal)
    if v == "flatten":
        return functools.partial(baselines.flatten_attention, causal=causal)
    if v == "hyper":
        return functools.partial(baselines.hyper_attention, causal=causal, seed=cfg.seed)
    if v == "primal":
        return functools.partial(baselines.primal_attention, causal=causal, seed=cfg.seed)
    if v == "linformer":
        if causal:
            raise ValueError("linformer baseline is non-causal only")
        return functools.partial(baselines.linformer_attention, seed=cfg.seed)
    raise ValueError(f"unknown attention variant {v!r}; expected one of {VARIANTS}")
