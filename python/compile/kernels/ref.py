"""Pure-jnp correctness oracles for every kernel in the stack.

These are the ground-truth implementations the Pallas kernels (and the
Rust-native engines) are tested against:

* ``exact_attention``       — the standard softmax attention.
* ``blocked_exact_attention`` — exact attention computed with the
  FlashAttention-2 double loop + online softmax (numerics oracle for the
  flash Pallas kernel).
* ``distr_attention_ref``   — DistrAttention (paper §3) with block-wise
  LSH grouping, sampling and fusion, written with plain jnp ops.
* ``distr_scores_ref``      — just the approximated score matrix Ŝ
  (used by the Table 3/4 error experiments).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import lsh


def exact_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool = False) -> jnp.ndarray:
    """Standard self-attention: softmax(Q K^T / sqrt(d)) V. Shapes (N, d)."""
    n, d = q.shape
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    if causal:
        mask = jnp.tril(jnp.ones((n, k.shape[0]), dtype=bool))
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return p @ v


def blocked_exact_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    block_l: int = 16,
    block_m: int = 16,
    causal: bool = False,
) -> jnp.ndarray:
    """Exact attention via the FlashAttention-2 schedule (paper §2.2.2).

    Outer loop over Q blocks of ``block_l`` rows; inner loop over K/V
    blocks of ``block_m`` rows with the online (m, l) softmax rescaling.
    Matches ``exact_attention`` to float tolerance.
    """
    n, d = q.shape
    nk = k.shape[0]
    assert n % block_l == 0 and nk % block_m == 0
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    def q_block_body(iq, qb):
        def kv_body(jk, carry):
            o, m_i, l_i = carry
            kb = jax.lax.dynamic_slice_in_dim(k, jk * block_m, block_m)
            vb = jax.lax.dynamic_slice_in_dim(v, jk * block_m, block_m)
            s = (qb @ kb.T) * scale
            if causal:
                rows = iq * block_l + jnp.arange(block_l)[:, None]
                cols = jk * block_m + jnp.arange(block_m)[None, :]
                s = jnp.where(rows >= cols, s, -jnp.inf)
            m_new = jnp.maximum(m_i, s.max(axis=-1))
            # Guard fully-masked rows: exp(-inf - -inf) otherwise NaNs.
            safe_m = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - safe_m[:, None])
            alpha = jnp.exp(jnp.where(jnp.isneginf(m_i), -jnp.inf, m_i) - safe_m)
            alpha = jnp.where(jnp.isneginf(m_i), 0.0, alpha)
            l_new = alpha * l_i + p.sum(axis=-1)
            o_new = alpha[:, None] * o + p @ vb
            return o_new, m_new, l_new

        o0 = jnp.zeros((block_l, d), jnp.float32)
        m0 = jnp.full((block_l,), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((block_l,), jnp.float32)
        o, m_i, l_i = jax.lax.fori_loop(0, nk // block_m, kv_body, (o0, m0, l0))
        return o / jnp.where(l_i == 0.0, 1.0, l_i)[:, None]

    qb = q.reshape(n // block_l, block_l, d)
    out = jax.vmap(q_block_body)(jnp.arange(n // block_l), qb)
    return out.reshape(n, d)


def distr_scores_block(
    q_block: jnp.ndarray,
    k: jnp.ndarray,
    perm: jnp.ndarray,
    group: int,
    sample: str = "first",
) -> jnp.ndarray:
    """Ŝ block: approximated scores of one Q block against all of K."""
    q_s, k_f = lsh.group_sample_fuse(q_block, k, perm, group, sample=sample)
    return q_s @ k_f.T


@functools.partial(
    jax.jit, static_argnames=("block_l", "group", "sample", "seed", "center")
)
def distr_scores_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    block_l: int,
    group: int,
    sample: str = "mean",
    seed: int = 0,
    center: bool = True,
) -> jnp.ndarray:
    """The full approximated (unscaled) score matrix Ŝ ≈ Q K^T.

    This is the quantity whose error the paper analyses in Tables 3/4
    and Figure 7 (no softmax, no 1/sqrt(d) scaling).
    """
    n, d = q.shape
    perms = lsh.block_permutations(q, block_l, seed=seed, center=center)
    qb = q.reshape(n // block_l, block_l, d)
    s_blocks = jax.vmap(lambda b, p: distr_scores_block(b, k, p, group, sample))(qb, perms)
    return s_blocks.reshape(n, k.shape[0])


@functools.partial(
    jax.jit,
    static_argnames=("block_l", "block_m", "group", "sample", "causal", "seed", "center"),
)
def distr_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    block_l: int = 16,
    block_m: int = 16,
    group: int = 2,
    sample: str = "mean",
    causal: bool = False,
    seed: int = 0,
    center: bool = True,
) -> jnp.ndarray:
    """DistrAttention oracle: Ŝ from block-wise LSH grouping, then the
    ordinary softmax(·/sqrt(d)) V pipeline (V is never reduced).

    ``block_m`` only affects the iteration structure, not the numerics,
    so we compute row blocks of Ŝ in one shot here; the Pallas kernel
    follows the true double loop.
    """
    n, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    perms = lsh.block_permutations(q, block_l, seed=seed, center=center)
    qb = q.reshape(n // block_l, block_l, d)

    def one_block(iq, q_blk, perm):
        s = distr_scores_block(q_blk, k, perm, group, sample) * scale
        if causal:
            rows = iq * block_l + jnp.arange(block_l)[:, None]
            cols = jnp.arange(k.shape[0])[None, :]
            s = jnp.where(rows >= cols, s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        return p @ v

    out = jax.vmap(one_block)(jnp.arange(n // block_l), qb, perms)
    return out.reshape(n, d)


def multihead(fn):
    """Lift an (N, d) single-head attention fn to (H, N, d)."""

    def wrapped(q, k, v, *args, **kwargs):
        return jax.vmap(lambda a, b, c: fn(a, b, c, *args, **kwargs))(q, k, v)

    return wrapped
