"""Locality-sensitive hashing for DistrAttention column grouping (paper §3.2).

A column ``q`` of a Q block (length ``l``) is mapped to an integer hash:

1. random projection into an ``N' = 16``-dimensional space,
2. sign binarization (positive -> 1, otherwise 0),
3. the bit pattern is decoded through a Gray-code table so that bit
   patterns at small Hamming distance land on nearby integers.

Sorting the ``d`` hashes of a block yields the index permutation that
places similar columns next to each other; consecutive runs of ``G*``
indices form the sampling/fusion groups.

Everything here is pure jnp so it lowers into the same HLO module as the
Pallas kernel (the paper also treats LSH grouping as a separate
lightweight step, cf. §4.8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# N' in the paper: the projection dimensionality, chosen to match the
# fixed tile size accepted by the matrix units (tensor cores on the
# paper's GPUs, MXU tiles here).
N_PRIME = 16


def projection_matrix(block_l: int, seed: int = 0, n_prime: int = N_PRIME) -> jnp.ndarray:
    """The random projection ``P in R^{N' x l}``, generated once per shape.

    The paper generates the projection "in prior" (fixed at model build
    time); we derive it deterministically from ``seed`` so the AOT
    artifact, the reference oracle and the Rust implementation agree.
    """
    rng = np.random.RandomState(seed ^ (block_l * 0x9E3779B1 % (2**31)))
    proj = rng.standard_normal((n_prime, block_l)).astype(np.float32)
    return jnp.asarray(proj)


def gray_decode(g: jnp.ndarray, bits: int = N_PRIME) -> jnp.ndarray:
    """Decode a binary-reflected Gray code to its integer rank.

    Two Gray codes at Hamming distance 1 decode to integers that are
    close in value, which is what makes sorting the decoded values group
    similar sign patterns together.
    """
    b = g.astype(jnp.uint32)
    shift = 1
    while shift < bits:
        b = b ^ (b >> shift)
        shift <<= 1
    return b.astype(jnp.int32)


def hash_columns(block: jnp.ndarray, proj: jnp.ndarray, center: bool = True) -> jnp.ndarray:
    """Hash each column of ``block`` (shape ``(l, d)``) to an int32.

    Returns shape ``(d,)``: the LSH values of the ``d`` columns.

    ``center=True`` subtracts the per-row mean across columns before
    projecting, so the hashing hyperplanes pass through the column
    cloud's centroid. The paper hashes raw columns; for the all-positive
    activations (and the paper's uniform(0,1) synthetic workload) raw
    sign bits are weakly discriminative, and centering recovers the
    error magnitudes Table 3 reports (see EXPERIMENTS.md tab3/tab4).
    """
    x = block - block.mean(axis=1, keepdims=True) if center else block
    # (N', l) @ (l, d) -> (N', d): one projected vector per column.
    projected = proj @ x
    bits = (projected > 0).astype(jnp.uint32)
    weights = (2 ** jnp.arange(proj.shape[0], dtype=jnp.uint32))[:, None]
    codes = jnp.sum(bits * weights, axis=0)
    return gray_decode(codes, bits=proj.shape[0])


def block_permutation(block: jnp.ndarray, proj: jnp.ndarray, center: bool = True) -> jnp.ndarray:
    """The sorted-hash index permutation for one Q block (paper Fig. 5).

    Ties are broken by column index (the key is ``hash * d + col``), so
    the permutation is unique and identical across every backend the HLO
    runs on — XLA's sort stability flag does not survive all transport
    paths, and the Rust engine must reproduce the exact grouping.
    """
    d = block.shape[1]
    h = hash_columns(block, proj, center=center)
    # hash < 2^16 and d <= 2^8, so the combined key fits in int32
    key = h.astype(jnp.int32) * d + jnp.arange(d, dtype=jnp.int32)
    return jnp.argsort(key)


@functools.partial(jax.jit, static_argnames=("block_l", "seed", "center"))
def block_permutations(
    q: jnp.ndarray, block_l: int, seed: int = 0, center: bool = True
) -> jnp.ndarray:
    """Permutations for every Q block: ``(N/block_l, d)`` int32.

    ``q`` has shape ``(N, d)``; each row block of size ``block_l`` gets
    its own permutation (paper §3.3: re-deriving the permutation per
    block bounds the LSH error and lets consecutive K blocks reuse it).
    """
    n, d = q.shape
    assert n % block_l == 0, f"N={n} not divisible by block_l={block_l}"
    proj = projection_matrix(block_l, seed=seed)
    blocks = q.reshape(n // block_l, block_l, d)
    return jax.vmap(lambda b: block_permutation(b, proj, center))(blocks)


def group_sample_fuse(
    q_block: jnp.ndarray,
    k: jnp.ndarray,
    perm: jnp.ndarray,
    group: int,
    sample: str = "first",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Apply the paper's sampling (Q) and fusion (K) along d.

    ``q_block``: (l, d); ``k``: (m, d) (rows of K == columns of K^T);
    ``perm``: (d,) grouping permutation. Returns ``(q_s, k_f)`` with
    shapes ``(l, d/group)`` and ``(m, d/group)`` such that
    ``q_s @ k_f.T`` approximates ``q_block @ k.T``.
    """
    l, d = q_block.shape
    assert d % group == 0, f"d={d} not divisible by group={group}"
    qp = jnp.take(q_block, perm, axis=1).reshape(l, d // group, group)
    kp = jnp.take(k, perm, axis=1).reshape(k.shape[0], d // group, group)
    if sample == "first":
        q_s = qp[:, :, 0]
    elif sample == "mean":
        q_s = qp.mean(axis=2)
    else:  # pragma: no cover - guarded by callers
        raise ValueError(f"unknown sample mode {sample!r}")
    k_f = kp.sum(axis=2)
    return q_s, k_f
