"""The DistrAttention Pallas kernel (the paper's §3 contribution).

Pipeline per Q block (one grid step):

1. take the block's LSH permutation (computed once per block by
   ``lsh.block_permutations`` — the separate "lightweight grouping" step
   the paper measures in §4.8),
2. *sampling*: permute the block's d columns and keep one column per
   group of ``G*`` (``q_s``: ``(l, d/G*)``),
3. inner loop over K blocks: *fusion* — permute the K block's columns
   (= rows of K^T) and sum each group (``k_f``: ``(m, d/G*)``),
4. ``Ŝ_blk = q_s @ k_f^T`` — d/G* multiplications per element instead of
   d — then the standard FlashAttention-2 online softmax and ``P V``
   accumulation (V is never reduced, so the output shape is unchanged).

The contraction shrinks from d to d/G*, which on the paper's GPUs frees
tensor-core time and shrinks the SMEM working set; on TPU the analogous
win is fewer MXU passes and a smaller VMEM Q/K footprint (DESIGN.md §2).

`interpret=True`: see flash.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import lsh
from .flash import NEG_INF


def _distr_kernel(
    q_ref,
    k_ref,
    v_ref,
    perm_ref,
    o_ref,
    *,
    block_m: int,
    group: int,
    causal: bool,
    block_l: int,
    sample: str,
):
    iq = pl.program_id(0)
    q = q_ref[...]                      # (block_l, d)
    perm = perm_ref[...].reshape(-1)    # (d,) this block's grouping permutation
    l, d = q.shape
    n_kv = k_ref.shape[0]
    dg = d // group
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    # Sampling: one estimate column per group (paper keeps a single
    # q̂_j; "mean" is the averaged-estimate ablation).
    qp = jnp.take(q, perm, axis=1).reshape(l, dg, group)
    q_s = qp.mean(axis=2) if sample == "mean" else qp[:, :, 0]

    def body(jk, carry):
        o, m_i, l_i = carry
        kb = pl.load(k_ref, (pl.dslice(jk * block_m, block_m), slice(None)))
        vb = pl.load(v_ref, (pl.dslice(jk * block_m, block_m), slice(None)))
        # Fusion: sum the K^T rows of each group. Reuses the *same*
        # permutation for every K block in this row of Ŝ blocks — this
        # is why the paper samples Q and not K^T (§3.3).
        k_f = jnp.take(kb, perm, axis=1).reshape(block_m, dg, group).sum(axis=2)
        s = jnp.dot(q_s, k_f.T) * scale  # (l, m) from a d/G* contraction
        if causal:
            rows = iq * block_l + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = jk * block_m + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_new = jnp.maximum(m_i, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + p.sum(axis=-1)
        o_new = alpha[:, None] * o + jnp.dot(p, vb)
        return o_new, m_new, l_new

    o0 = jnp.zeros((l, d), jnp.float32)
    m0 = jnp.full((l,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((l,), jnp.float32)
    n_blocks = (iq + 1) * block_l // block_m if causal else n_kv // block_m
    o, m_i, l_i = jax.lax.fori_loop(0, n_blocks, body, (o0, m0, l0))
    o_ref[...] = o / jnp.where(l_i == 0.0, 1.0, l_i)[:, None]


@functools.partial(
    jax.jit,
    static_argnames=("block_l", "block_m", "group", "causal", "sample", "seed", "center"),
)
def distr_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    block_l: int = 16,
    block_m: int = 16,
    group: int = 2,
    causal: bool = False,
    sample: str = "mean",
    seed: int = 0,
    center: bool = True,
) -> jnp.ndarray:
    """DistrAttention over single-head (N, d) inputs.

    The LSH permutations are derived outside the kernel (cheap, §4.8)
    and streamed in per Q block; sampling, fusion, the reduced-d score
    matmul, online softmax and PV all fuse into one kernel — the paper's
    "single CUDA kernel" property that the baselines lack (§4.3).
    """
    n, d = q.shape
    n_kv = k.shape[0]
    assert n % block_l == 0 and n_kv % block_m == 0 and d % group == 0
    if causal:
        assert block_l % block_m == 0
    perms = lsh.block_permutations(q, block_l, seed=seed, center=center).astype(jnp.int32)
    kernel = functools.partial(
        _distr_kernel,
        block_m=block_m,
        group=group,
        causal=causal,
        block_l=block_l,
        sample=sample,
    )
    return pl.pallas_call(
        kernel,
        grid=(n // block_l,),
        in_specs=[
            pl.BlockSpec((block_l, d), lambda i: (i, 0)),
            pl.BlockSpec((n_kv, d), lambda i: (0, 0)),
            pl.BlockSpec((n_kv, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),  # this block's permutation
        ],
        out_specs=pl.BlockSpec((block_l, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(q, k, v, perms)


def make_distr_attention_vjp(
    block_l=16, block_m=16, group=2, causal=False, sample="mean", seed=0, center=True
):
    """Trainable DistrAttention: Pallas forward, jnp-reference backward.

    The permutation is data-dependent but piecewise constant, so the
    gradient treats the grouping as fixed (straight-through w.r.t. the
    gather/sum) — exactly the gradient of the jnp reference, which
    computes the same Ŝ.
    """
    from . import ref

    def ref_fn(q, k, v):
        return ref.distr_attention_ref(
            q, k, v, block_l=block_l, block_m=block_m, group=group,
            sample=sample, causal=causal, seed=seed, center=center,
        )

    @jax.custom_vjp
    def attn(q, k, v):
        return distr_attention(
            q, k, v, block_l=block_l, block_m=block_m, group=group,
            causal=causal, sample=sample, seed=seed, center=center,
        )

    def fwd(q, k, v):
        return attn(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, pullback = jax.vjp(ref_fn, q, k, v)
        return pullback(g)

    attn.defvjp(fwd, bwd)
    return attn
