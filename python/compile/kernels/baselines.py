"""Approximate-attention baselines from the paper's evaluation (§4.1).

The paper compares DistrAttention against Primal [6], Hyper [18],
Flatten [15], Hydra [3] (plus exact Attn-Standard and FlashAttention-2).
Full-fidelity ports of four research codebases are out of scope; each
baseline here implements the mechanism the paper *describes it by* —
the property that drives its accuracy/latency behaviour in Tables 5-8:

* Hydra  — head-per-dimension linear attention; the attention matrix is
  never formed (why it collapses without fine-tuning, Table 8).
* Hyper  — LSH row-sort + block-diagonal exact attention + sampled
  residual columns (sub-quadratic, loses cross-block token info).
* Flatten — focused linear attention: relu-power feature map + a local
  rank-restoration term standing in for the paper's DWC module.
* Primal — low-rank (Nyström-style landmark) approximation of softmax
  attention, standing in for the KSVD primal-dual form; introduces
  extra projection work, which is why Primal's TTFT is *worse* than
  standard at short lengths (Table 6).
* Linformer — fixed projection of K/V along N (related-work baseline
  used in the attention-time sweeps).

All are deliberately pure jnp: they represent the "cannot fuse into a
single kernel" property the paper contrasts with (§4.3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _l2norm(x, axis=-1, eps=1e-6):
    return x / (jnp.linalg.norm(x, axis=axis, keepdims=True) + eps)


def hydra_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool = False) -> jnp.ndarray:
    """Hydra attention [3]: H = d heads, cosine-similarity kernel.

    O = φ(Q) ⊙ Σ_n (φ(K)_n ⊙ V_n): global KV summary, O(N d) — no
    pairwise attention matrix at all.
    """
    qn, kn = _l2norm(q), _l2norm(k)
    if causal:
        kv = jnp.cumsum(kn * v, axis=0)
        return qn * kv
    kv = jnp.sum(kn * v, axis=0, keepdims=True)
    return qn * kv


def flatten_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, p: int = 3, causal: bool = False
) -> jnp.ndarray:
    """Focused linear attention (Flatten Transformer [15]).

    Feature map ``φ(x) = ||x|| · relu(x)^p / ||relu(x)^p||`` sharpens the
    attention distribution; a cheap local smoothing term restores the
    rank the softmax-free form loses (stand-in for the paper's
    depth-wise conv on V).
    """
    def phi(x):
        fx = jnp.maximum(x, 0.0) ** p
        return _l2norm(fx) * jnp.linalg.norm(x, axis=-1, keepdims=True)

    qf, kf = phi(q), phi(k)
    if causal:
        kv = jnp.cumsum(kf[:, :, None] * v[:, None, :], axis=0)     # (N, d, d)
        z = jnp.cumsum(kf, axis=0)                                   # (N, d)
        num = jnp.einsum("nd,nde->ne", qf, kv)
        den = jnp.sum(qf * z, axis=-1, keepdims=True) + 1e-6
    else:
        kv = kf.T @ v                                                # (d, d)
        z = kf.sum(axis=0)                                           # (d,)
        num = qf @ kv
        den = (qf @ z)[:, None] + 1e-6
    out = num / den
    # rank restoration: local average of V (DWC stand-in). Causal mode
    # only looks backward (a wrap-around roll would leak future tokens).
    prev1 = jnp.concatenate([jnp.zeros_like(v[:1]), v[:-1]], axis=0)
    if causal:
        prev2 = jnp.concatenate([jnp.zeros_like(v[:2]), v[:-2]], axis=0)
        local = (v + prev1 + prev2) / 3.0
    else:
        nxt = jnp.concatenate([v[1:], jnp.zeros_like(v[:1])], axis=0)
        local = (v + prev1 + nxt) / 3.0
    return out + 0.1 * local


def hyper_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    block: int = 16,
    n_samples: int = 16,
    seed: int = 0,
    causal: bool = False,
) -> jnp.ndarray:
    """HyperAttention [18]: sortLSH block-diagonal + sampled residual.

    Rows of Q and K are hashed (random projection sign bits), sorted,
    and exact attention runs inside each diagonal block of the sorted
    order; ``n_samples`` uniformly sampled K rows approximate the mass
    outside the diagonal blocks.

    Causal mode keeps the original token order (sorting would interleave
    future and past tokens — the cumsum limit the paper cites for linear
    methods) and masks both the diagonal blocks and the sampled residual
    by position, so it is strictly causal.
    """
    n, d = q.shape
    rng = np.random.RandomState(seed)
    proj = jnp.asarray(rng.standard_normal((d, 8)).astype(np.float32))
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    def hash_rows(x):
        bits = (x @ proj > 0).astype(jnp.int32)
        return jnp.sum(bits * (2 ** jnp.arange(8, dtype=jnp.int32)), axis=-1)

    if causal:
        pq = jnp.arange(n)
        pk = pq
    else:
        pq = jnp.argsort(hash_rows(q))
        pk = jnp.argsort(hash_rows(k))
    qs, ks, vs = q[pq], k[pk], v[pk]
    nb = n // block
    qb = qs.reshape(nb, block, d)
    kb = ks.reshape(nb, block, d)
    vb = vs.reshape(nb, block, d)

    def block_attn(qi, ki, vi, bi):
        s = qi @ ki.T * scale
        if causal:
            rows = jnp.arange(block)[:, None]
            cols = jnp.arange(block)[None, :]
            s = jnp.where(rows >= cols, s, -1e30)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        return p @ vi, p.sum(axis=-1), m[:, 0]

    o_d, l_d, m_d = jax.vmap(block_attn)(qb, kb, vb, jnp.arange(nb))

    if n_samples > 0:
        idx = jnp.sort(jnp.asarray(rng.choice(n, size=n_samples, replace=False)))
        ks_s, vs_s = k[idx], v[idx]
        s_r = qs @ ks_s.T * scale                       # (N, n_samples)
        if causal:
            # residual may only reference sampled positions in the past,
            # and never positions already covered by the diagonal block
            row_pos = jnp.arange(n)[:, None]
            blk_start = (jnp.arange(n) // block * block)[:, None]
            ok = (idx[None, :] < blk_start) & (idx[None, :] <= row_pos)
            s_r = jnp.where(ok, s_r, -1e30)
        s_r = s_r.reshape(nb, block, n_samples)
        m_new = jnp.maximum(m_d, s_r.max(axis=-1))
        alpha = jnp.exp(m_d - m_new)
        p_r = jnp.exp(s_r - m_new[..., None]) * (n / max(n_samples, 1))
        o = o_d * alpha[..., None] + jnp.einsum("bns,se->bne", p_r, vs_s)
        l = l_d * alpha + p_r.sum(axis=-1)
    else:
        o, l = o_d, l_d
    out_sorted = (o / l[..., None]).reshape(n, d)
    inv = jnp.argsort(pq)
    return out_sorted[inv]


def primal_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    rank: int = 16,
    seed: int = 0,
    causal: bool = False,
) -> jnp.ndarray:
    """Primal-style low-rank attention: Nyström landmarks as the
    low-rank factorization of the (asymmetric-kernel) attention matrix.

    Extra projection matmuls model the "additional parameters" the paper
    blames for Primal's slow short-sequence TTFT (Table 6).

    Causal mode reconstructs *logits* low-rank, masks them, and applies a
    softmax (materializes S̃ — faithfully expensive). Token content leaks
    only through the landmark basis (a known property of Nyström-style
    causal approximations); non-landmark future tokens cannot influence
    earlier outputs.
    """
    n, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    m = min(rank, n)
    stride = max(n // m, 1)
    landmarks_k = k[::stride][:m]
    landmarks_q = q[::stride][:m]
    if causal:
        # logits-space low-rank reconstruction: S̃ = (Q Lk^T)(Lq Lk^T)^+(Lq K^T)
        f0 = q @ landmarks_k.T * scale                                # (N, m)
        a = landmarks_q @ landmarks_k.T * scale                       # (m, m)
        b = landmarks_q @ k.T * scale                                 # (m, N)
        a_pinv = jnp.linalg.pinv(a + 1e-4 * jnp.eye(m))
        s_tilde = f0 @ a_pinv @ b                                     # (N, N)
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        s_tilde = jnp.where(mask, s_tilde, -1e30)
        p = jax.nn.softmax(s_tilde, axis=-1)
        return p @ v
    f0 = jax.nn.softmax(q @ landmarks_k.T * scale, axis=-1)          # (N, m)
    a = jax.nn.softmax(landmarks_q @ landmarks_k.T * scale, axis=-1)  # (m, m)
    b = jax.nn.softmax(landmarks_q @ k.T * scale, axis=-1)            # (m, N)
    a_pinv = jnp.linalg.pinv(a + 1e-4 * jnp.eye(m))
    return f0 @ (a_pinv @ (b @ v))


def linformer_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, rank: int = 32, seed: int = 0
) -> jnp.ndarray:
    """Linformer [40]: project K/V along the token axis with a fixed
    random E/F (rank × N), then exact attention in the reduced space."""
    n, d = q.shape
    rng = np.random.RandomState(seed)
    e = jnp.asarray(rng.standard_normal((rank, n)).astype(np.float32)) / np.sqrt(rank)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    kp, vp = e @ k, e @ v                         # (rank, d)
    p = jax.nn.softmax(q @ kp.T * scale, axis=-1)  # (N, rank)
    return p @ vp


BASELINES = {
    "hydra": hydra_attention,
    "flatten": flatten_attention,
    "hyper": hyper_attention,
    "primal": primal_attention,
    "linformer": linformer_attention,
}
