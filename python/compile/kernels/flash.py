"""FlashAttention-2 as a Pallas kernel (the paper's exact-attention baseline).

Schedule (paper §2.2.2, Fig. 3): the grid parallelizes over Q blocks
(threadblocks on the paper's GPUs); inside the kernel body an inner loop
iterates over K^T/V blocks with the online softmax rescaling, so S and P
are never materialized to HBM.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers the kernel to plain HLO ops that
both the pytest oracle checks and the Rust runtime can run. On a real
TPU the same BlockSpec structure expresses the HBM->VMEM schedule the
paper implements with shared-memory staging (DESIGN.md §2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30  # finite stand-in for -inf: keeps exp() well-defined in-kernel


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_m: int, causal: bool, block_l: int):
    """One grid step = one Q block. k_ref/v_ref hold the full K/V."""
    iq = pl.program_id(0)
    q = q_ref[...]  # (block_l, d)
    n_kv = k_ref.shape[0]
    d = q.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    def body(jk, carry):
        o, m_i, l_i = carry
        kb = pl.load(k_ref, (pl.dslice(jk * block_m, block_m), slice(None)))
        vb = pl.load(v_ref, (pl.dslice(jk * block_m, block_m), slice(None)))
        s = jnp.dot(q, kb.T) * scale
        if causal:
            rows = iq * block_l + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = jk * block_m + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_new = jnp.maximum(m_i, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + p.sum(axis=-1)
        o_new = alpha[:, None] * o + jnp.dot(p, vb)
        return o_new, m_new, l_new

    o0 = jnp.zeros(q.shape, jnp.float32)
    m0 = jnp.full((q.shape[0],), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q.shape[0],), jnp.float32)
    if causal:
        # Only K blocks up to (and including) the diagonal contribute.
        n_blocks = (iq + 1) * block_l // block_m
    else:
        n_blocks = n_kv // block_m
    o, m_i, l_i = jax.lax.fori_loop(0, n_blocks, body, (o0, m0, l0))
    o_ref[...] = o / jnp.where(l_i == 0.0, 1.0, l_i)[:, None]


@functools.partial(jax.jit, static_argnames=("block_l", "block_m", "causal"))
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    block_l: int = 16,
    block_m: int = 16,
    causal: bool = False,
) -> jnp.ndarray:
    """Exact attention with the FlashAttention-2 block schedule. (N, d)."""
    n, d = q.shape
    n_kv = k.shape[0]
    assert n % block_l == 0 and n_kv % block_m == 0
    if causal:
        assert block_l % block_m == 0, "causal kernel needs block_l % block_m == 0"
    kernel = functools.partial(_flash_kernel, block_m=block_m, causal=causal, block_l=block_l)
    return pl.pallas_call(
        kernel,
        grid=(n // block_l,),
        in_specs=[
            pl.BlockSpec((block_l, d), lambda i: (i, 0)),  # stream one Q block per step
            pl.BlockSpec((n_kv, d), lambda i: (0, 0)),     # K resident across steps
            pl.BlockSpec((n_kv, d), lambda i: (0, 0)),     # V resident across steps
        ],
        out_specs=pl.BlockSpec((block_l, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(q, k, v)


flash_attention_mh = jax.vmap(
    lambda q, k, v, block_l, block_m, causal: flash_attention(
        q, k, v, block_l=block_l, block_m=block_m, causal=causal
    ),
    in_axes=(0, 0, 0, None, None, None),
)
