"""Layer-2 models: a ViT-style encoder classifier and a Llama-style
causal decoder, both with pluggable attention (compile.attention_api).

Plain-dict parameters + pure functions (no flax): every forward here is
lowered once by aot.py to HLO text and then executed from the Rust
runtime; Python never runs at serve time.

Scale substitutions vs the paper (DESIGN.md §5): ViT-tiny instead of
ViT-Base (S4), a ~6M-param Llama-style decoder instead of Llama3-1B
(S6). The per-head dimension d — the axis DistrAttention acts on — is
kept at the paper's value (64).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .attention_api import AttentionConfig, make_attention


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    image_size: int = 32
    patch_size: int = 4
    channels: int = 3
    d_model: int = 128
    n_heads: int = 2          # d_head = 64, the paper's per-head dim
    n_layers: int = 4
    mlp_ratio: int = 4
    n_classes: int = 10

    @property
    def n_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    @property
    def seq_len(self) -> int:
        # +1 cls token, padded to a multiple of 16 so every block size
        # divides it (N' alignment, paper Eq. 4).
        raw = self.n_patches + 1
        return (raw + 15) // 16 * 16

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def patch_dim(self) -> int:
        return self.patch_size * self.patch_size * self.channels


@dataclasses.dataclass(frozen=True)
class LMConfig:
    vocab: int = 512
    d_model: int = 256
    n_heads: int = 4          # d_head = 64
    n_layers: int = 4
    d_ff: int = 512
    max_seq: int = 256

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------


def layer_norm(x, gamma, beta, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def rms_norm(x, gamma, eps=1e-5):
    return x / jnp.sqrt((x**2).mean(axis=-1, keepdims=True) + eps) * gamma


def rope(x: jnp.ndarray, base: float = 10000.0) -> jnp.ndarray:
    """Rotary position embedding over (..., N, d)."""
    n, d = x.shape[-2], x.shape[-1]
    half = d // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = jnp.arange(n, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def multi_head_attention(params, x, attn_fn: Callable, n_heads: int, use_rope: bool = False):
    """x: (N, D) -> (N, D); heads run the per-head attn_fn via vmap."""
    n, dm = x.shape
    dh = dm // n_heads
    q = (x @ params["wq"]).reshape(n, n_heads, dh).transpose(1, 0, 2)
    k = (x @ params["wk"]).reshape(n, n_heads, dh).transpose(1, 0, 2)
    v = (x @ params["wv"]).reshape(n, n_heads, dh).transpose(1, 0, 2)
    if use_rope:
        q, k = rope(q), rope(k)
    o = jax.vmap(attn_fn)(q, k, v)  # (H, N, dh)
    o = o.transpose(1, 0, 2).reshape(n, dm)
    return o @ params["wo"]


def _dense(rng, n_in, n_out):
    return (rng.standard_normal((n_in, n_out)) * (1.0 / np.sqrt(n_in))).astype(np.float32)


# ---------------------------------------------------------------------------
# ViT-style encoder classifier
# ---------------------------------------------------------------------------


def vit_init(cfg: ViTConfig, seed: int = 0) -> dict:
    rng = np.random.RandomState(seed)
    params = {
        "patch_embed": _dense(rng, cfg.patch_dim, cfg.d_model),
        "cls_token": (rng.standard_normal((1, cfg.d_model)) * 0.02).astype(np.float32),
        "pos_embed": (rng.standard_normal((cfg.seq_len, cfg.d_model)) * 0.02).astype(np.float32),
        "head": _dense(rng, cfg.d_model, cfg.n_classes),
        "final_gamma": np.ones(cfg.d_model, np.float32),
        "final_beta": np.zeros(cfg.d_model, np.float32),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append(
            {
                "ln1_gamma": np.ones(cfg.d_model, np.float32),
                "ln1_beta": np.zeros(cfg.d_model, np.float32),
                "ln2_gamma": np.ones(cfg.d_model, np.float32),
                "ln2_beta": np.zeros(cfg.d_model, np.float32),
                "wq": _dense(rng, cfg.d_model, cfg.d_model),
                "wk": _dense(rng, cfg.d_model, cfg.d_model),
                "wv": _dense(rng, cfg.d_model, cfg.d_model),
                "wo": _dense(rng, cfg.d_model, cfg.d_model),
                "w1": _dense(rng, cfg.d_model, cfg.d_model * cfg.mlp_ratio),
                "w2": _dense(rng, cfg.d_model * cfg.mlp_ratio, cfg.d_model),
            }
        )
    return jax.tree.map(jnp.asarray, params)


def patchify(cfg: ViTConfig, images: jnp.ndarray) -> jnp.ndarray:
    """(B, H, W, C) -> (B, n_patches, patch_dim)."""
    b = images.shape[0]
    p, s = cfg.patch_size, cfg.image_size // cfg.patch_size
    x = images.reshape(b, s, p, s, p, cfg.channels)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, s * s, cfg.patch_dim)


def vit_forward(params, images, cfg: ViTConfig, attn_cfg: AttentionConfig) -> jnp.ndarray:
    """(B, H, W, C) images -> (B, n_classes) logits."""
    attn_fn = make_attention(attn_cfg, causal=False)

    def single(img):
        tokens = patchify(cfg, img[None])[0] @ params["patch_embed"]
        x = jnp.concatenate([params["cls_token"], tokens], axis=0)
        pad = cfg.seq_len - x.shape[0]
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad, cfg.d_model), jnp.float32)], axis=0)
        x = x + params["pos_embed"]
        for lp in params["layers"]:
            h = layer_norm(x, lp["ln1_gamma"], lp["ln1_beta"])
            x = x + multi_head_attention(lp, h, attn_fn, cfg.n_heads)
            h = layer_norm(x, lp["ln2_gamma"], lp["ln2_beta"])
            x = x + jax.nn.gelu(h @ lp["w1"]) @ lp["w2"]
        x = layer_norm(x, params["final_gamma"], params["final_beta"])
        return x[0] @ params["head"]  # cls token

    return jax.vmap(single)(images)


# ---------------------------------------------------------------------------
# Llama-style causal decoder
# ---------------------------------------------------------------------------


def lm_init(cfg: LMConfig, seed: int = 0) -> dict:
    rng = np.random.RandomState(seed)
    params = {
        "embed": (rng.standard_normal((cfg.vocab, cfg.d_model)) * 0.02).astype(np.float32),
        "final_gamma": np.ones(cfg.d_model, np.float32),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append(
            {
                "rms1_gamma": np.ones(cfg.d_model, np.float32),
                "rms2_gamma": np.ones(cfg.d_model, np.float32),
                "wq": _dense(rng, cfg.d_model, cfg.d_model),
                "wk": _dense(rng, cfg.d_model, cfg.d_model),
                "wv": _dense(rng, cfg.d_model, cfg.d_model),
                "wo": _dense(rng, cfg.d_model, cfg.d_model),
                "w_gate": _dense(rng, cfg.d_model, cfg.d_ff),
                "w_up": _dense(rng, cfg.d_model, cfg.d_ff),
                "w_down": _dense(rng, cfg.d_ff, cfg.d_model),
            }
        )
    return jax.tree.map(jnp.asarray, params)


def lm_forward(params, tokens, cfg: LMConfig, attn_cfg: AttentionConfig) -> jnp.ndarray:
    """(B, N) int32 tokens -> (B, N, vocab) logits. Causal."""
    attn_fn = make_attention(attn_cfg, causal=True)

    def single(toks):
        x = params["embed"][toks]
        for lp in params["layers"]:
            h = rms_norm(x, lp["rms1_gamma"])
            x = x + multi_head_attention(lp, h, attn_fn, cfg.n_heads, use_rope=True)
            h = rms_norm(x, lp["rms2_gamma"])
            x = x + (jax.nn.silu(h @ lp["w_gate"]) * (h @ lp["w_up"])) @ lp["w_down"]
        x = rms_norm(x, params["final_gamma"])
        return x @ params["embed"].T  # tied head

    return jax.vmap(single)(tokens)


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
