"""Table 5 + Figure 8: fine-tune the ViT classifier with every attention
mechanism on synthetic image datasets, then evaluate accuracy and
inference time.

Paper setup: ViT-Base fine-tuned 20 epochs on ImageNet/CIFAR/iNat.
Here (DESIGN.md §5 S3/S4): ViT-tiny on three class-prototype datasets
("syn10" ≈ CIFAR-10-like 10 classes, "syn100" ≈ CIFAR-100-like,
"syn10-hard" high-noise), trained a fixed number of steps from a shared
"pre-trained" initialization (the standard-attention model trained
first, mimicking fine-tuning from a pretrained checkpoint).

Outputs: results/tab5.md, results/fig8.md (loss curves).

Run from python/:  python -m experiments.vit_finetune [--quick]
"""

from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import model, train
from compile.attention_api import AttentionConfig

from .common import ImageDataset, ensure_results_dir, markdown_table

VARIANTS = ["primal", "hyper", "flatten", "hydra", "standard", "flash", "distr", "distr_flash"]

CFG = model.ViTConfig(d_model=128, n_heads=2, n_layers=4)


def make_datasets(quick: bool, micro: bool = False):
    if micro:
        return {"syn10": ImageDataset(10, noise=0.3, seed=1)}
    if quick:
        # learnable at quick step counts: lower noise, 2 datasets
        return {
            "syn10": ImageDataset(10, noise=0.35, seed=1),
            "syn10-hard": ImageDataset(10, noise=0.8, seed=3),
        }
    return {
        "syn10": ImageDataset(10, noise=0.35, seed=1),
        "syn100": ImageDataset(100, noise=0.3, seed=2),
        "syn10-hard": ImageDataset(10, noise=0.8, seed=3),
    }


def accuracy(params, ds, acfg, cfg, batches=8, batch=32, seed0=10_000):
    """(ACC1, ACC5) on held-out batches."""
    top1 = top5 = total = 0
    for b in range(batches):
        imgs, labels = ds.batch(batch, seed0 + b)
        logits = np.asarray(model.vit_forward(params, jnp.asarray(imgs), cfg, acfg))
        order = np.argsort(-logits, axis=1)
        top1 += (order[:, 0] == labels).sum()
        top5 += np.any(order[:, :5] == labels[:, None], axis=1).sum()
        total += batch
    return top1 / total * 100.0, top5 / total * 100.0


def pretrain_standard(cfg, ds, steps, seed=0):
    """The shared 'pre-trained checkpoint': standard attention."""
    params = model.vit_init(cfg, seed=seed)
    acfg = AttentionConfig(variant="standard")
    step = jax.jit(train.make_vit_train_step(cfg, acfg, lr=1e-3))
    opt = train.adamw_init(params)
    for s in range(steps):
        imgs, labels = ds.batch(32, s)
        params, opt, _ = step(params, opt, jnp.asarray(imgs), jnp.asarray(labels))
    return params


def finetune(params0, cfg, ds, variant, steps, lr):
    acfg = AttentionConfig(
        variant=variant, block_l=16, block_m=16, group=2,
        trainable=(variant == "distr_flash"),
    )
    # the flash Pallas kernel has no VJP; train through the numerically
    # identical standard attention and evaluate with the flash kernel
    train_acfg = AttentionConfig(variant="standard") if variant == "flash" else acfg
    step = jax.jit(train.make_vit_train_step(cfg, train_acfg, lr=lr))
    params = params0
    opt = train.adamw_init(params)
    losses = []
    for s in range(steps):
        imgs, labels = ds.batch(32, 50_000 + s)
        params, opt, loss = step(params, opt, jnp.asarray(imgs), jnp.asarray(labels))
        losses.append(float(loss))
    return params, acfg, losses


def inference_time(params, cfg, acfg, ds, batches=4, batch=32):
    imgs, _ = ds.batch(batch, 777)
    imgs = jnp.asarray(imgs)
    fwd = jax.jit(lambda p, x: model.vit_forward(p, x, cfg, acfg))
    fwd(params, imgs).block_until_ready()  # compile
    t0 = time.time()
    for _ in range(batches):
        fwd(params, imgs).block_until_ready()
    return (time.time() - t0) / batches


def main():
    quick = "--quick" in sys.argv
    micro = "--micro" in sys.argv
    steps = 15 if micro else (100 if quick else 200)
    ft_steps = 10 if micro else (40 if quick else 100)
    datasets = make_datasets(quick, micro)
    global VARIANTS
    if micro:
        VARIANTS = ["hydra", "hyper", "standard", "flash", "distr_flash"]
    out_dir = ensure_results_dir()

    results: dict = {}
    curves: dict = {}
    t_start = time.time()
    for ds_name, ds in datasets.items():
        print(f"=== dataset {ds_name}: pretraining standard checkpoint ({steps} steps)")
        params0 = pretrain_standard(CFG, ds, steps)
        for variant in VARIANTS:
            t0 = time.time()
            if variant in ("standard", "flash"):
                # exact attention: the checkpoint IS the model (paper
                # skips fine-tuning exact attention on the pretrain set)
                params, acfg, losses = finetune(params0, CFG, ds, variant, ft_steps // 4, 5e-4)
            else:
                params, acfg, losses = finetune(params0, CFG, ds, variant, ft_steps, 5e-4)
            acc1, acc5 = accuracy(params, ds, acfg, CFG)
            infer_s = inference_time(params, CFG, acfg, ds)
            results.setdefault(variant, {})[ds_name] = {
                "acc1": acc1, "acc5": acc5, "infer_s": infer_s,
            }
            curves.setdefault(ds_name, {})[variant] = losses
            print(f"  {variant:12s} ACC1 {acc1:5.1f} ACC5 {acc5:5.1f} "
                  f"infer {infer_s*1e3:6.1f} ms  ({time.time()-t0:.0f}s)")

    # tab5.md
    header = ["Method"] + [f"{d} ACC1/ACC5" for d in datasets] + ["Infer (ms, syn10)"]
    rows = []
    for variant in VARIANTS:
        row = [variant]
        for d in datasets:
            r = results[variant][d]
            row.append(f"{r['acc1']:.1f} / {r['acc5']:.1f}")
        row.append(f"{results[variant]['syn10']['infer_s']*1e3:.1f}")
        rows.append(row)
    text = (
        "Table 5 (reproduction) — ViT fine-tuning across attention mechanisms on\n"
        "synthetic datasets (DESIGN.md S3/S4). Paper's claim to check: DistrAttention\n"
        "is the most accurate approximate mechanism, within ~1% of exact attention.\n\n"
        + markdown_table(header, rows)
    )
    with open(os.path.join(out_dir, "tab5.md"), "w") as f:
        f.write(text)

    # fig8.md — loss curves, 10-bucket means per variant
    lines = ["Figure 8 (reproduction) — fine-tuning loss curves (10-bucket means).",
             "Paper's claim: ours tracks standard attention closely; lowest loss among",
             "approximate mechanisms.", ""]
    for ds_name, by_variant in curves.items():
        lines.append(f"## {ds_name}")
        for variant, losses in by_variant.items():
            buckets = np.array_split(np.array(losses), min(10, len(losses)))
            spark = " ".join(f"{b.mean():.3f}" for b in buckets)
            lines.append(f"  {variant:12s} {spark}")
        lines.append("")
    with open(os.path.join(out_dir, "fig8.md"), "w") as f:
        f.write("\n".join(lines))

    with open(os.path.join(out_dir, "tab5.json"), "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {out_dir}/tab5.md, fig8.md ({time.time()-t_start:.0f}s total)")


if __name__ == "__main__":
    main()
