"""Shared pieces for the fine-tuning experiments (Tables 5/7, Fig 8).

Synthetic datasets substitute for the paper's ImageNet/CIFAR/iNaturalist
and MathInstruct/MMLU (DESIGN.md §5 S3/S5): class-prototype images and
modular-arithmetic token sequences, both deterministic.
"""

from __future__ import annotations

import os

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def ensure_results_dir() -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return RESULTS_DIR


class ImageDataset:
    """Class-prototype images: prototype + uniform noise, clamped [0,1]."""

    def __init__(self, classes: int, size: int = 32, channels: int = 3,
                 noise: float = 0.6, seed: int = 0):
        rng = np.random.RandomState(seed)
        self.classes = classes
        self.size = size
        self.channels = channels
        self.noise = noise
        self.prototypes = rng.rand(classes, size, size, channels).astype(np.float32)

    def batch(self, n: int, seed: int):
        rng = np.random.RandomState(seed)
        labels = rng.randint(0, self.classes, size=n)
        noise = (rng.rand(n, self.size, self.size, self.channels).astype(np.float32) - 0.5)
        imgs = np.clip(self.prototypes[labels] + self.noise * noise, 0.0, 1.0)
        return imgs, labels.astype(np.int32)


class SeqDataset:
    """Modular-arithmetic sequences (mirrors rust workload::SeqTask)."""

    def __init__(self, vocab: int, seq_len: int):
        self.vocab = vocab
        self.seq_len = seq_len

    def batch(self, n: int, seed: int):
        rng = np.random.RandomState(seed)
        toks = np.zeros((n, self.seq_len), np.int32)
        for i in range(n):
            a = 1 + (1 + rng.randint(6)) * 2
            b = rng.randint(self.vocab // 2)
            x = 8 + rng.randint(self.vocab - 8)
            toks[i, 0] = a % 8
            toks[i, 1] = b % 8
            for t in range(2, self.seq_len):
                toks[i, t] = x
                x = (a * x + b) % (self.vocab - 8) + 8
        targets = np.roll(toks, -1, axis=1)
        targets[:, -1] = toks[:, 0]
        return toks, targets


def markdown_table(header: list[str], rows: list[list[str]]) -> str:
    widths = [len(h) for h in header]
    for row in rows:
        widths = [max(w, len(c)) for w, c in zip(widths, row)]
    fmt = lambda cells: "| " + " | ".join(c.ljust(w) for c, w in zip(cells, widths)) + " |"
    lines = [fmt(header), "|" + "|".join("-" * (w + 2) for w in widths) + "|"]
    lines += [fmt(r) for r in rows]
    return "\n".join(lines) + "\n"
