"""Table 7 (+ Table 6 accuracy columns): fine-tune the Llama-style LM
with each attention mechanism on the synthetic modular-arithmetic task
and measure next-token exact-match accuracy at different sequence
lengths.

Paper setup: Llama3-1B on MathInstruct, tested on MMLU-math at token
lengths 256/512. Here (DESIGN.md §5 S5/S6): the ~3M-param decoder on
modular-arithmetic sequences at lengths 64/128 — the same question
(how much accuracy does each approximate attention give up vs exact?)
with an exactly measurable answer.

Outputs: results/tab7.md.

Run from python/:  python -m experiments.lm_finetune [--quick]
"""

from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import model, train
from compile.attention_api import AttentionConfig

from .common import SeqDataset, ensure_results_dir, markdown_table

VARIANTS = ["flatten", "primal", "hydra", "hyper", "flash", "standard", "distr_flash"]

CFG = model.LMConfig(vocab=64, d_model=128, n_heads=2, n_layers=3, d_ff=256)


def next_token_accuracy(params, acfg, seq_len, batches=8, batch=16, seed0=90_000):
    """Exact-match accuracy of next-token prediction on the second half
    of each sequence (where context is established)."""
    ds = SeqDataset(CFG.vocab, seq_len)
    hit = total = 0
    for b in range(batches):
        toks, targets = ds.batch(batch, seed0 + b)
        logits = np.asarray(model.lm_forward(params, jnp.asarray(toks), CFG, acfg))
        pred = logits.argmax(-1)
        half = seq_len // 2
        hit += (pred[:, half:-1] == targets[:, half:-1]).sum()
        total += pred[:, half:-1].size
    return hit / total * 100.0


def finetune(variant, seq_len, steps, seed=0):
    acfg = AttentionConfig(
        variant=variant, block_l=16, block_m=16, group=2,
        trainable=(variant == "distr_flash"),
    )
    # the flash Pallas kernel has no VJP; train through the numerically
    # identical standard attention and evaluate with the flash kernel
    train_acfg = AttentionConfig(variant="standard") if variant == "flash" else acfg
    params = model.lm_init(CFG, seed=seed)
    step = jax.jit(train.make_lm_train_step(CFG, train_acfg, lr=2e-3))
    opt = train.adamw_init(params)
    ds = SeqDataset(CFG.vocab, seq_len)
    for s in range(steps):
        toks, targets = ds.batch(16, s)
        params, opt, loss = step(params, opt, jnp.asarray(toks), jnp.asarray(targets))
    return params, acfg, float(loss)


def main():
    quick = "--quick" in sys.argv
    steps = 60 if quick else 300
    seq_lens = [64] if quick else [64, 128]
    out_dir = ensure_results_dir()

    results: dict = {}
    for seq_len in seq_lens:
        print(f"=== seq_len {seq_len}, {steps} train steps per variant")
        for variant in VARIANTS:
            t0 = time.time()
            params, acfg, final_loss = finetune(variant, seq_len, steps)
            acc = next_token_accuracy(params, acfg, seq_len)
            results.setdefault(variant, {})[seq_len] = {"acc": acc, "loss": final_loss}
            print(f"  {variant:12s} acc {acc:5.1f}%  loss {final_loss:.3f}  "
                  f"({time.time()-t0:.0f}s)")

    header = ["Method"] + [f"n={n} acc%" for n in seq_lens]
    rows = []
    for variant in VARIANTS:
        rows.append([variant] + [f"{results[variant][n]['acc']:.1f}" for n in seq_lens])
    text = (
        "Table 7 (reproduction) — LM fine-tuning accuracy by attention mechanism\n"
        "on the synthetic arithmetic-sequence task (DESIGN.md S5/S6). Paper's\n"
        "claim to check: ours within ~1-2% of exact attention, ahead of most\n"
        "approximate baselines.\n\n" + markdown_table(header, rows)
    )
    with open(os.path.join(out_dir, "tab7.md"), "w") as f:
        f.write(text)
    with open(os.path.join(out_dir, "tab7.json"), "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {out_dir}/tab7.md")


if __name__ == "__main__":
    main()
