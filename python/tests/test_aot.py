"""AOT pipeline tests: manifest integrity + HLO round-trip executability.

The round-trip test compiles emitted HLO text back through XLA and
compares against the live jax function — the same path the Rust runtime
takes (minus the text parser reassigning instruction ids).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.attention_api import AttentionConfig
from compile.kernels import distr, ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


class TestManifest:
    def test_format_version(self):
        assert manifest()["format"] == 1

    def test_all_files_exist(self):
        m = manifest()
        for name, entry in m["artifacts"].items():
            assert os.path.exists(os.path.join(ART, entry["file"])), name
            if "params" in entry:
                assert os.path.exists(os.path.join(ART, entry["params"]["bin"]))
                assert os.path.exists(os.path.join(ART, entry["params"]["index"]))

    def test_expected_artifacts_present(self):
        m = manifest()["artifacts"]
        for required in (
            "attn_exact_256x64",
            "attn_flash_256x64",
            "attn_distr_256x64_g2",
            "lm_prefill_distr_flash_128",
            "lm_train_step",
            "vit_fwd_standard_b8",
        ):
            assert required in m, f"missing artifact {required}"

    def test_io_spec_shapes(self):
        m = manifest()["artifacts"]
        e = m["attn_exact_256x64"]
        assert e["inputs"] == [{"shape": [256, 64], "dtype": "f32"}] * 3
        assert e["outputs"] == [{"shape": [256, 64], "dtype": "f32"}]

    def test_train_step_io_counts(self):
        m = manifest()["artifacts"]
        e = m["lm_train_step"]
        n_p, n_o = e["meta"]["n_params"], e["meta"]["n_opt"]
        assert len(e["inputs"]) == n_p + n_o + 2     # + tokens + targets
        assert len(e["outputs"]) == n_p + n_o + 1    # + loss

    def test_params_bin_size_matches_index(self):
        m = manifest()["artifacts"]
        for entry in m["artifacts"].values() if False else m.values():
            if "params" not in entry:
                continue
            with open(os.path.join(ART, entry["params"]["index"])) as f:
                idx = json.load(f)
            size = os.path.getsize(os.path.join(ART, entry["params"]["bin"]))
            assert size == idx["total_bytes"]
            assert sum(l["numel"] for l in idx["leaves"]) * 4 == size


class TestHloRoundTrip:
    def _run_hlo(self, text, inputs):
        from jaxlib._jax import DeviceList

        # HLO text -> proto -> stablehlo, then through jax's CPU client —
        # mirrors the Rust runtime path (HloModuleProto::from_text_file).
        comp = xc._xla.hlo_module_from_text(text)
        stablehlo = xc._xla.mlir.hlo_to_stablehlo(comp.as_serialized_hlo_module_proto())
        client = jax.devices("cpu")[0].client
        exe = client.compile_and_load(stablehlo, DeviceList(tuple(client.devices())))
        bufs = [client.buffer_from_pyval(x) for x in inputs]
        out = exe.execute(bufs)
        return [np.asarray(o) for o in out]

    def test_attention_artifact_executes(self, rng):
        m = manifest()["artifacts"]
        with open(os.path.join(ART, m["attn_distr_256x64_g2"]["file"])) as f:
            text = f.read()
        q = rng.rand(256, 64).astype(np.float32)
        k = rng.rand(256, 64).astype(np.float32)
        v = rng.rand(256, 64).astype(np.float32)
        out = self._run_hlo(text, [q, k, v])
        live = distr.distr_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), 16, 16, group=2
        )
        # requires aot.to_hlo_text's print_large_constants=True: the
        # default HLO printer elides the LSH projection constant, which
        # parses back as zeros and silently regroups every block.
        np.testing.assert_allclose(out[0], np.asarray(live), atol=1e-5)

    def test_exact_artifact_matches_oracle(self, rng):
        m = manifest()["artifacts"]
        with open(os.path.join(ART, m["attn_exact_256x64"]["file"])) as f:
            text = f.read()
        q = rng.rand(256, 64).astype(np.float32)
        k = rng.rand(256, 64).astype(np.float32)
        v = rng.rand(256, 64).astype(np.float32)
        out = self._run_hlo(text, [q, k, v])
        live = ref.exact_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
        np.testing.assert_allclose(out[0], np.asarray(live), atol=1e-5)


class TestParamExport:
    def test_lm_params_roundtrip(self):
        m = manifest()["artifacts"]
        entry = m["lm_prefill_standard_128"]
        with open(os.path.join(ART, entry["params"]["index"])) as f:
            idx = json.load(f)
        blob = np.fromfile(os.path.join(ART, entry["params"]["bin"]), dtype="<f4")
        params = model.lm_init(aot.LM_CFG, seed=0)
        flat = jax.tree.leaves(params)
        assert len(idx["leaves"]) == len(flat)
        for leaf_info, live in zip(idx["leaves"], flat):
            seg = blob[leaf_info["offset"] // 4:][: leaf_info["numel"]]
            np.testing.assert_allclose(seg, np.asarray(live).ravel(), atol=0)

    def test_leaf_order_matches_manifest_inputs(self):
        # rust feeds params.bin leaves in index order as the leading
        # executable inputs — shapes must line up exactly.
        m = manifest()["artifacts"]
        entry = m["lm_prefill_standard_128"]
        with open(os.path.join(ART, entry["params"]["index"])) as f:
            idx = json.load(f)
        for leaf_info, in_spec in zip(idx["leaves"], entry["inputs"]):
            numel = int(np.prod(in_spec["shape"]))
            assert numel == leaf_info["numel"], (leaf_info, in_spec)
