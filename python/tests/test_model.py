"""Layer-2 model tests: shapes, variant swapping, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, train
from compile.attention_api import VARIANTS, AttentionConfig

LM_SMALL = model.LMConfig(vocab=64, d_model=128, n_heads=2, n_layers=2, d_ff=256)
VIT_SMALL = model.ViTConfig(d_model=128, n_heads=2, n_layers=2, n_classes=10)


@pytest.fixture(scope="module")
def lm_params():
    return model.lm_init(LM_SMALL, seed=0)


@pytest.fixture(scope="module")
def vit_params():
    return model.vit_init(VIT_SMALL, seed=0)


class TestLM:
    def test_forward_shape(self, lm_params):
        toks = jnp.zeros((2, 64), jnp.int32)
        acfg = AttentionConfig(variant="standard")
        logits = model.lm_forward(lm_params, toks, LM_SMALL, acfg)
        assert logits.shape == (2, 64, 64)

    def test_causality(self, lm_params, rng):
        # changing a later token must not change earlier logits
        toks = jnp.asarray(rng.randint(0, 64, (1, 64)), jnp.int32)
        acfg = AttentionConfig(variant="distr_flash", group=2)
        l1 = np.asarray(model.lm_forward(lm_params, toks, LM_SMALL, acfg))
        toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % 64)
        l2 = np.asarray(model.lm_forward(lm_params, toks2, LM_SMALL, acfg))
        np.testing.assert_allclose(l1[0, :32], l2[0, :32], atol=1e-4)

    @pytest.mark.parametrize("variant", [v for v in VARIANTS if v != "linformer"])
    def test_all_variants_run(self, lm_params, variant):
        toks = jnp.zeros((1, 64), jnp.int32)
        acfg = AttentionConfig(variant=variant, block_l=16, block_m=16, group=2)
        logits = model.lm_forward(lm_params, toks, LM_SMALL, acfg)
        assert logits.shape == (1, 64, 64)
        assert np.isfinite(np.asarray(logits)).all()

    def test_distr_close_to_standard(self, lm_params, rng):
        # swap-in property (paper §4.6): same weights, approximate
        # attention, predictions stay close. Random-init logits hover
        # near zero, so compare next-token distributions, not raw rel-err.
        toks = jnp.asarray(rng.randint(0, 64, (1, 64)), jnp.int32)
        exact = model.lm_forward(lm_params, toks, LM_SMALL, AttentionConfig(variant="standard"))
        approx = model.lm_forward(
            lm_params, toks, LM_SMALL, AttentionConfig(variant="distr_flash", group=2)
        )
        hydra = model.lm_forward(lm_params, toks, LM_SMALL, AttentionConfig(variant="hydra"))

        def corr(a):
            pa = np.asarray(jax.nn.softmax(a, axis=-1)).ravel()
            pe = np.asarray(jax.nn.softmax(exact, axis=-1)).ravel()
            return np.corrcoef(pe, pa)[0, 1]

        c_distr, c_hydra = corr(approx), corr(hydra)
        # random-init logits are near-flat, so exact agreement is noise;
        # require distr to track the exact model far better than the
        # matrix-free baseline, and well at absolute level
        assert c_distr > 0.8, f"distr swap-in drift too large: corr={c_distr}"
        assert c_distr > c_hydra, f"distr ({c_distr}) not closer than hydra ({c_hydra})"

    def test_flash_equals_standard(self, lm_params, rng):
        toks = jnp.asarray(rng.randint(0, 64, (1, 64)), jnp.int32)
        exact = model.lm_forward(lm_params, toks, LM_SMALL, AttentionConfig(variant="standard"))
        fl = model.lm_forward(lm_params, toks, LM_SMALL, AttentionConfig(variant="flash"))
        np.testing.assert_allclose(np.asarray(fl), np.asarray(exact), atol=1e-4)

    def test_rope_shift_changes_logits(self, lm_params, rng):
        # RoPE must make position matter
        toks = jnp.asarray(rng.randint(1, 64, (1, 64)), jnp.int32)
        rolled = jnp.roll(toks, 7, axis=1)
        acfg = AttentionConfig(variant="standard")
        l1 = model.lm_forward(lm_params, toks, LM_SMALL, acfg)
        l2 = model.lm_forward(lm_params, rolled, LM_SMALL, acfg)
        assert float(jnp.abs(l1 - l2).max()) > 1e-3


class TestViT:
    def test_forward_shape(self, vit_params, rng):
        imgs = jnp.asarray(rng.rand(2, 32, 32, 3).astype(np.float32))
        logits = model.vit_forward(vit_params, imgs, VIT_SMALL, AttentionConfig(variant="standard"))
        assert logits.shape == (2, 10)

    def test_patchify_roundtrip_count(self):
        cfg = VIT_SMALL
        imgs = jnp.arange(2 * 32 * 32 * 3, dtype=jnp.float32).reshape(2, 32, 32, 3)
        patches = model.patchify(cfg, imgs)
        assert patches.shape == (2, cfg.n_patches, cfg.patch_dim)
        # content preserved
        assert float(patches.sum()) == pytest.approx(float(imgs.sum()), rel=1e-6)

    def test_seq_len_is_16_aligned(self):
        assert VIT_SMALL.seq_len % 16 == 0

    @pytest.mark.parametrize("variant", ["standard", "flash", "distr", "distr_flash", "hydra"])
    def test_variants_run(self, vit_params, rng, variant):
        imgs = jnp.asarray(rng.rand(1, 32, 32, 3).astype(np.float32))
        acfg = AttentionConfig(variant=variant, block_l=16, block_m=16, group=2)
        logits = model.vit_forward(vit_params, imgs, VIT_SMALL, acfg)
        assert logits.shape == (1, 10)
        assert np.isfinite(np.asarray(logits)).all()

    def test_distr_swap_in_close(self, vit_params, rng):
        imgs = jnp.asarray(rng.rand(2, 32, 32, 3).astype(np.float32))
        exact = model.vit_forward(vit_params, imgs, VIT_SMALL, AttentionConfig(variant="standard"))
        approx = model.vit_forward(
            vit_params, imgs, VIT_SMALL, AttentionConfig(variant="distr_flash", group=2)
        )
        # logits needn't be identical but top-1 should usually agree on
        # random nets; require correlation instead of argmax equality
        c = np.corrcoef(np.asarray(exact).ravel(), np.asarray(approx).ravel())[0, 1]
        assert c > 0.95


class TestTraining:
    def test_lm_loss_decreases(self, rng):
        cfg = LM_SMALL
        params = model.lm_init(cfg, seed=1)
        acfg = AttentionConfig(variant="distr_flash", group=2, trainable=True)
        step = jax.jit(train.make_lm_train_step(cfg, acfg, lr=1e-3))
        opt = train.adamw_init(params)
        toks = jnp.asarray(rng.randint(0, 64, (4, 64)), jnp.int32)
        tgts = jnp.roll(toks, -1, axis=1)
        losses = []
        for _ in range(8):
            params, opt, loss = step(params, opt, toks, tgts)
            losses.append(float(loss))
        assert losses[-1] < losses[0], f"no learning: {losses}"

    def test_vit_loss_decreases(self, rng):
        cfg = VIT_SMALL
        params = model.vit_init(cfg, seed=1)
        acfg = AttentionConfig(variant="distr", group=2)
        step = jax.jit(train.make_vit_train_step(cfg, acfg, lr=1e-3))
        opt = train.adamw_init(params)
        imgs = jnp.asarray(rng.rand(8, 32, 32, 3).astype(np.float32))
        labels = jnp.asarray(rng.randint(0, 10, (8,)), jnp.int32)
        losses = []
        for _ in range(8):
            params, opt, loss = step(params, opt, imgs, labels)
            losses.append(float(loss))
        assert losses[-1] < losses[0], f"no learning: {losses}"

    def test_sgd_momentum_update(self):
        params = {"w": jnp.ones((2, 2))}
        grads = {"w": jnp.full((2, 2), 0.5)}
        mom = train.sgd_init(params)
        p2, m2 = train.sgd_update(params, grads, mom, lr=0.1, beta=0.9)
        np.testing.assert_allclose(np.asarray(p2["w"]), 1.0 - 0.1 * 0.5)
        p3, _ = train.sgd_update(p2, grads, m2, lr=0.1, beta=0.9)
        # momentum accelerates the second step
        assert float(p2["w"][0, 0] - p3["w"][0, 0]) > 0.05

    def test_adamw_t_increments(self):
        params = {"w": jnp.ones(3)}
        opt = train.adamw_init(params)
        p2, o2 = train.adamw_update(params, {"w": jnp.ones(3)}, opt)
        assert float(o2["t"]) == 1.0
        _, o3 = train.adamw_update(p2, {"w": jnp.ones(3)}, o2)
        assert float(o3["t"]) == 2.0

    def test_cross_entropy_perfect_prediction(self):
        logits = jnp.full((1, 4, 8), -20.0)
        targets = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        logits = logits.at[0, jnp.arange(4), targets[0]].set(20.0)
        assert float(train.cross_entropy_lm(logits, targets)) < 1e-3
