"""Pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes and block configurations; fixed tests pin the
paper's specific workloads (N=64, d=64, uniform(0,1) — §4.2).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import distr, flash, ref
from tests.conftest import make_qkv


class TestFlashKernel:
    @pytest.mark.parametrize("n,d", [(64, 64), (128, 64), (64, 128), (256, 32)])
    def test_matches_exact(self, rng, n, d):
        q, k, v = map(jnp.asarray, make_qkv(rng, n, d))
        out = flash.flash_attention(q, k, v, 16, 16)
        expect = ref.exact_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)

    @pytest.mark.parametrize("bl,bm", [(16, 16), (32, 16), (16, 32), (64, 64), (32, 64)])
    def test_block_size_invariance(self, rng, bl, bm):
        # exactness must be independent of the (l, m) schedule choice
        q, k, v = map(jnp.asarray, make_qkv(rng, 64, 64))
        out = flash.flash_attention(q, k, v, bl, bm)
        expect = ref.exact_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)

    @pytest.mark.parametrize("bl,bm", [(16, 16), (32, 16), (64, 32)])
    def test_causal(self, rng, bl, bm):
        q, k, v = map(jnp.asarray, make_qkv(rng, 128, 64, dist="normal"))
        out = flash.flash_attention(q, k, v, bl, bm, causal=True)
        expect = ref.exact_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)

    def test_causal_first_row_is_v0(self, rng):
        # row 0 attends only to itself
        q, k, v = map(jnp.asarray, make_qkv(rng, 32, 32))
        out = flash.flash_attention(q, k, v, 16, 16, causal=True)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(v[0]), atol=1e-5)

    def test_blocked_ref_matches_exact_causal(self, rng):
        q, k, v = map(jnp.asarray, make_qkv(rng, 64, 32, dist="normal"))
        out = ref.blocked_exact_attention(q, k, v, 16, 16, causal=True)
        expect = ref.exact_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)

    def test_large_magnitude_stability(self, rng):
        # online softmax must not overflow for large logits
        q = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32) * 30)
        k = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32) * 30)
        v = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
        out = flash.flash_attention(q, k, v, 16, 16)
        assert np.isfinite(np.asarray(out)).all()
        expect = ref.exact_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4)

    @given(
        n_exp=st.integers(min_value=5, max_value=8),
        d=st.sampled_from([16, 32, 64, 128]),
        bl_exp=st.integers(min_value=4, max_value=6),
        seed=st.integers(min_value=0, max_value=100),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_matches_exact(self, n_exp, d, bl_exp, seed):
        rng = np.random.RandomState(seed)
        n, bl = 2**n_exp, 2**bl_exp
        if bl > n:
            bl = n
        q, k, v = map(jnp.asarray, make_qkv(rng, n, d, dist="normal"))
        out = flash.flash_attention(q, k, v, bl, 16)
        expect = ref.exact_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


class TestDistrKernel:
    @pytest.mark.parametrize("group", [1, 2, 4, 8])
    def test_matches_reference(self, rng, group):
        q, k, v = map(jnp.asarray, make_qkv(rng, 64, 64))
        out = distr.distr_attention(q, k, v, 16, 16, group=group)
        expect = ref.distr_attention_ref(q, k, v, 16, 16, group=group)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)

    def test_group1_is_exact(self, rng):
        # G*=1: no fusion — must reproduce exact attention (paper §3.1)
        q, k, v = map(jnp.asarray, make_qkv(rng, 64, 64))
        out = distr.distr_attention(q, k, v, 16, 16, group=1)
        expect = ref.exact_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)

    @pytest.mark.parametrize("sample", ["first", "mean"])
    def test_sample_modes(self, rng, sample):
        q, k, v = map(jnp.asarray, make_qkv(rng, 64, 64))
        out = distr.distr_attention(q, k, v, 16, 16, group=2, sample=sample)
        expect = ref.distr_attention_ref(q, k, v, 16, 16, group=2, sample=sample)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)

    def test_causal_matches_reference(self, rng):
        q, k, v = map(jnp.asarray, make_qkv(rng, 128, 64, dist="normal"))
        out = distr.distr_attention(q, k, v, 16, 16, group=2, causal=True)
        expect = ref.distr_attention_ref(q, k, v, 16, 16, group=2, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)

    def test_identical_column_pairs_exact(self, rng):
        # duplicate columns in Q AND matching duplicate structure means
        # grouping loses nothing -> distr == exact even at G*=2
        base_q = rng.rand(64, 32).astype(np.float32)
        q = jnp.asarray(np.repeat(base_q, 2, axis=1))
        k = jnp.asarray(np.repeat(rng.rand(64, 32).astype(np.float32), 2, axis=1))
        v = jnp.asarray(rng.rand(64, 64).astype(np.float32))
        out = distr.distr_attention(q, k, v, 16, 16, group=2, sample="first")
        expect = ref.exact_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)

    def test_output_shape_preserved(self, rng):
        # the paper's flexibility claim: d reduction never changes the
        # output shape (§4.3)
        for group in (2, 4, 8):
            q, k, v = map(jnp.asarray, make_qkv(rng, 64, 64))
            out = distr.distr_attention(q, k, v, 16, 16, group=group)
            assert out.shape == (64, 64)

    def test_approximation_error_band(self, rng):
        # paper §4.2: mean relative Ŝ error ~1% at G*=2 on uniform(0,1)
        errs = []
        for rep in range(10):
            q, k, _ = make_qkv(rng, 64, 64)
            s = q @ k.T
            sh = np.asarray(ref.distr_scores_ref(jnp.asarray(q), jnp.asarray(k), 2, 2, seed=rep))
            errs.append(np.abs(sh - s) / np.abs(s))
        mean_err = float(np.mean([e.mean() for e in errs]))
        assert mean_err < 0.03, f"mean rel err {mean_err:.4f} out of band"

    def test_error_grows_with_group(self, rng):
        # Table 4 shape: error increases monotonically-ish with G*
        means = []
        for group in (2, 4, 8, 16):
            errs = []
            for rep in range(5):
                q, k, _ = make_qkv(rng, 64, 64)
                s = q @ k.T
                sh = np.asarray(
                    ref.distr_scores_ref(jnp.asarray(q), jnp.asarray(k), 2, group, seed=rep)
                )
                errs.append((np.abs(sh - s) / np.abs(s)).mean())
            means.append(np.mean(errs))
        assert means[0] < means[-1], f"error not growing: {means}"

    @given(
        n=st.sampled_from([32, 64, 128]),
        d=st.sampled_from([32, 64, 128]),
        group=st.sampled_from([2, 4]),
        bl=st.sampled_from([16, 32]),
        seed=st.integers(min_value=0, max_value=50),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_kernel_equals_ref(self, n, d, group, bl, seed):
        rng = np.random.RandomState(seed)
        if bl > n:
            bl = n
        q, k, v = map(jnp.asarray, make_qkv(rng, n, d, dist="normal"))
        out = distr.distr_attention(q, k, v, bl, 16, group=group, seed=seed)
        expect = ref.distr_attention_ref(q, k, v, bl, 16, group=group, seed=seed)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)

    def test_rejects_indivisible_shapes(self, rng):
        q, k, v = map(jnp.asarray, make_qkv(rng, 60, 64))
        with pytest.raises(AssertionError):
            distr.distr_attention(q, k, v, 16, 16, group=2)


class TestDistrVjp:
    def test_gradients_flow(self, rng):
        import jax

        attn = distr.make_distr_attention_vjp(block_l=16, block_m=16, group=2)
        q, k, v = map(jnp.asarray, make_qkv(rng, 32, 32, dist="normal"))

        def loss(q, k, v):
            return (attn(q, k, v) ** 2).sum()

        grads = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
        for g in grads:
            assert g.shape == (32, 32)
            assert np.isfinite(np.asarray(g)).all()
            assert float(jnp.abs(g).max()) > 0

    def test_grad_matches_ref_grad(self, rng):
        import jax

        attn = distr.make_distr_attention_vjp(block_l=16, block_m=16, group=2, seed=1)
        q, k, v = map(jnp.asarray, make_qkv(rng, 32, 32, dist="normal"))

        def loss_kernel(q, k, v):
            return (attn(q, k, v) ** 2).sum()

        def loss_ref(q, k, v):
            o = ref.distr_attention_ref(q, k, v, 16, 16, group=2, seed=1)
            return (o**2).sum()

        g1 = jax.grad(loss_kernel, argnums=0)(q, k, v)
        g2 = jax.grad(loss_ref, argnums=0)(q, k, v)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)
