"""Baseline approximate-attention mechanisms: shape/causality/sanity.

These baselines only need to be *faithful stand-ins* (DESIGN.md §5);
the tests pin the properties the paper's comparison depends on.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import baselines, ref
from tests.conftest import make_qkv

ALL = list(baselines.BASELINES.items())


class TestShapes:
    @pytest.mark.parametrize("name,fn", ALL)
    def test_preserves_output_shape(self, rng, name, fn):
        q, k, v = map(jnp.asarray, make_qkv(rng, 64, 32))
        out = fn(q, k, v)
        assert out.shape == (64, 32)

    @pytest.mark.parametrize("name,fn", ALL)
    def test_finite(self, rng, name, fn):
        q, k, v = map(jnp.asarray, make_qkv(rng, 64, 32, dist="normal"))
        assert np.isfinite(np.asarray(fn(q, k, v))).all()


class TestCausal:
    @pytest.mark.parametrize("name", ["hydra", "flatten", "hyper", "primal"])
    def test_causal_no_future_leak(self, rng, name):
        # perturb a future token; causal output at position 0..t must not change
        fn = baselines.BASELINES[name]
        q, k, v = map(jnp.asarray, make_qkv(rng, 32, 16, dist="normal"))
        out1 = np.asarray(fn(q, k, v, causal=True))
        k2 = k.at[-1].set(k[-1] + 10.0)
        v2 = v.at[-1].set(v[-1] - 5.0)
        out2 = np.asarray(fn(q, k2, v2, causal=True))
        np.testing.assert_allclose(out1[: 32 // 2], out2[: 32 // 2], atol=1e-4)


class TestMechanisms:
    def test_hydra_no_attention_matrix(self, rng):
        # hydra is linear in N: doubling N with duplicated rows keeps
        # per-row outputs consistent under global-summary semantics
        q, k, v = map(jnp.asarray, make_qkv(rng, 16, 8))
        out = baselines.hydra_attention(q, k, v)
        # manual: qn * sum(kn*v)
        qn = np.asarray(q) / (np.linalg.norm(q, axis=-1, keepdims=True) + 1e-6)
        kn = np.asarray(k) / (np.linalg.norm(k, axis=-1, keepdims=True) + 1e-6)
        expect = qn * (kn * np.asarray(v)).sum(0, keepdims=True)
        np.testing.assert_allclose(np.asarray(out), expect, atol=1e-4)

    def test_hyper_closer_than_hydra_to_exact(self, rng):
        # hyper keeps block-diagonal exact attention; on clustered data it
        # should beat the matrix-free hydra
        errs = {"hyper": [], "hydra": []}
        for rep in range(5):
            q, k, v = map(jnp.asarray, make_qkv(rng, 64, 32, dist="normal"))
            exact = np.asarray(ref.exact_attention(q, k, v))
            for name in errs:
                out = np.asarray(baselines.BASELINES[name](q, k, v))
                errs[name].append(np.abs(out - exact).mean())
        assert np.mean(errs["hyper"]) < np.mean(errs["hydra"])

    def test_primal_rank_improves_accuracy(self, rng):
        errs = []
        for rank in (4, 16, 64):
            q, k, v = map(jnp.asarray, make_qkv(rng, 64, 32))
            exact = np.asarray(ref.exact_attention(q, k, v))
            out = np.asarray(baselines.primal_attention(q, k, v, rank=rank))
            errs.append(np.abs(out - exact).mean())
        assert errs[-1] <= errs[0] * 1.5  # higher rank no (much) worse

    def test_linformer_full_rank_is_projection_limited(self, rng):
        q, k, v = map(jnp.asarray, make_qkv(rng, 64, 32))
        out = baselines.linformer_attention(q, k, v, rank=32)
        assert out.shape == (64, 32)

    def test_linformer_rejects_causal(self, rng):
        from compile.attention_api import AttentionConfig, make_attention

        with pytest.raises(ValueError):
            make_attention(AttentionConfig(variant="linformer"), causal=True)


class TestDistrBeatsBaselines:
    def test_distr_most_accurate_approximation(self, rng):
        # the paper's headline accuracy claim (§4.3): DistrAttention is
        # the most accurate approximate mechanism. Check output-space
        # MAE vs exact on the synthesized workload.
        errors = {}
        for rep in range(5):
            q, k, v = map(jnp.asarray, make_qkv(rng, 64, 64))
            exact = np.asarray(ref.exact_attention(q, k, v))
            d_out = np.asarray(ref.distr_attention_ref(q, k, v, 16, 16, group=2, seed=rep))
            errors.setdefault("distr", []).append(np.abs(d_out - exact).mean())
            for name, fn in ALL:
                out = np.asarray(fn(q, k, v))
                errors.setdefault(name, []).append(np.abs(out - exact).mean())
        means = {k: float(np.mean(v)) for k, v in errors.items()}
        best = min(means, key=means.get)
        assert best == "distr", f"distr not most accurate: {means}"
