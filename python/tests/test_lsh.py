"""Unit tests for the LSH grouping pipeline (paper §3.2)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import lsh


def gray_encode(b: int) -> int:
    return b ^ (b >> 1)


class TestGrayDecode:
    def test_inverts_gray_encode(self):
        vals = np.arange(2**12, dtype=np.uint32)
        encoded = np.array([gray_encode(int(v)) for v in vals], dtype=np.uint32)
        decoded = np.asarray(lsh.gray_decode(jnp.asarray(encoded), bits=16))
        np.testing.assert_array_equal(decoded, vals)

    def test_hamming_neighbours_decode_nearby(self):
        # flipping bit k of the Gray code moves the decoded rank by
        # at most 2^(k+1) (locality property used for sorting)
        base = 0b1011001110001011
        for k in range(16):
            a = int(lsh.gray_decode(jnp.asarray([base], dtype=jnp.uint32))[0])
            b = int(lsh.gray_decode(jnp.asarray([base ^ (1 << k)], dtype=jnp.uint32))[0])
            assert abs(a - b) <= 2 ** (k + 1)

    @given(st.integers(min_value=0, max_value=2**16 - 1))
    @settings(max_examples=50, deadline=None)
    def test_bijective_on_16_bits(self, g):
        d = int(lsh.gray_decode(jnp.asarray([g], dtype=jnp.uint32))[0])
        assert gray_encode(d) == g


class TestProjection:
    def test_shape_and_determinism(self):
        p1 = lsh.projection_matrix(16, seed=3)
        p2 = lsh.projection_matrix(16, seed=3)
        assert p1.shape == (lsh.N_PRIME, 16)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))

    def test_different_seeds_differ(self):
        p1 = lsh.projection_matrix(16, seed=0)
        p2 = lsh.projection_matrix(16, seed=1)
        assert not np.allclose(np.asarray(p1), np.asarray(p2))

    def test_different_block_sizes_differ(self):
        assert lsh.projection_matrix(8).shape == (16, 8)
        assert lsh.projection_matrix(32).shape == (16, 32)


class TestPermutations:
    def test_valid_permutation(self, rng):
        q = jnp.asarray(rng.rand(64, 32).astype(np.float32))
        perms = np.asarray(lsh.block_permutations(q, 16))
        assert perms.shape == (4, 32)
        for p in perms:
            assert sorted(p.tolist()) == list(range(32))

    def test_blocks_get_distinct_permutations(self, rng):
        # §3.3: per-block permutations differ (that's the error-limiting
        # mechanism) — with random data, identical ones are ~impossible.
        q = jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32))
        perms = np.asarray(lsh.block_permutations(q, 16))
        assert len({tuple(p) for p in perms}) > 1

    def test_identical_columns_group_adjacent(self, rng):
        # construct a block where column 2i+1 duplicates column 2i:
        # duplicates hash identically so they sort adjacently.
        base = rng.standard_normal((16, 8)).astype(np.float32)
        dup = np.repeat(base, 2, axis=1)  # (16, 16) pairs of identical cols
        perms = np.asarray(lsh.block_permutations(jnp.asarray(dup), 16))
        p = perms[0].tolist()
        for i in range(0, 16, 2):
            # each duplicate pair (2i, 2i+1) must land adjacently: equal
            # hashes sort into a contiguous run, stably ordered by index.
            assert abs(p.index(i) - p.index(i + 1)) == 1
        # and the underlying hashes of duplicates are equal
        proj = lsh.projection_matrix(16)
        h = np.asarray(lsh.hash_columns(jnp.asarray(dup), proj))
        np.testing.assert_array_equal(h[0::2], h[1::2])

    def test_deterministic(self, rng):
        q = jnp.asarray(rng.rand(64, 64).astype(np.float32))
        p1 = np.asarray(lsh.block_permutations(q, 16, seed=0))
        p2 = np.asarray(lsh.block_permutations(q, 16, seed=0))
        np.testing.assert_array_equal(p1, p2)

    def test_requires_divisible_n(self, rng):
        q = jnp.asarray(rng.rand(60, 32).astype(np.float32))
        with pytest.raises(AssertionError):
            lsh.block_permutations(q, 16)

    @given(
        n_blocks=st.integers(min_value=1, max_value=4),
        block_l=st.sampled_from([2, 8, 16]),
        d=st.sampled_from([16, 32, 64]),
        seed=st.integers(min_value=0, max_value=10),
    )
    @settings(max_examples=20, deadline=None)
    def test_permutation_property(self, n_blocks, block_l, d, seed):
        rng = np.random.RandomState(seed)
        q = jnp.asarray(rng.standard_normal((n_blocks * block_l, d)).astype(np.float32))
        perms = np.asarray(lsh.block_permutations(q, block_l, seed=seed))
        assert perms.shape == (n_blocks, d)
        for p in perms:
            assert sorted(p.tolist()) == list(range(d))


class TestGroupSampleFuse:
    def test_shapes(self, rng):
        qb = jnp.asarray(rng.rand(16, 64).astype(np.float32))
        k = jnp.asarray(rng.rand(32, 64).astype(np.float32))
        perm = jnp.arange(64)
        q_s, k_f = lsh.group_sample_fuse(qb, k, perm, 4)
        assert q_s.shape == (16, 16)
        assert k_f.shape == (32, 16)

    def test_identity_perm_group1_is_exact(self, rng):
        # G*=1 degenerates to the exact product (paper §3.1: |G_j|=1
        # gives Ŝ = S).
        qb = jnp.asarray(rng.rand(8, 16).astype(np.float32))
        k = jnp.asarray(rng.rand(8, 16).astype(np.float32))
        q_s, k_f = lsh.group_sample_fuse(qb, k, jnp.arange(16), 1)
        np.testing.assert_allclose(
            np.asarray(q_s @ k_f.T), np.asarray(qb @ k.T), rtol=1e-5
        )

    def test_identical_columns_zero_error(self, rng):
        # if grouped columns are exactly equal, sampling loses nothing:
        # q̂ * sum(k) == sum(q_i k_i) for equal q_i.
        col = rng.rand(8, 8).astype(np.float32)
        qb = jnp.asarray(np.repeat(col, 2, axis=1))  # pairs of equal columns
        k = jnp.asarray(rng.rand(8, 16).astype(np.float32))
        q_s, k_f = lsh.group_sample_fuse(qb, k, jnp.arange(16), 2, sample="first")
        np.testing.assert_allclose(
            np.asarray(q_s @ k_f.T), np.asarray(qb @ k.T), rtol=1e-5
        )

    def test_mean_equals_first_for_identical_columns(self, rng):
        col = rng.rand(8, 8).astype(np.float32)
        qb = jnp.asarray(np.repeat(col, 2, axis=1))
        k = jnp.asarray(rng.rand(8, 16).astype(np.float32))
        a, _ = lsh.group_sample_fuse(qb, k, jnp.arange(16), 2, sample="first")
        b, _ = lsh.group_sample_fuse(qb, k, jnp.arange(16), 2, sample="mean")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)

    def test_rejects_bad_sample_mode(self, rng):
        qb = jnp.asarray(rng.rand(8, 16).astype(np.float32))
        with pytest.raises(ValueError):
            lsh.group_sample_fuse(qb, qb, jnp.arange(16), 2, sample="median")

    def test_rejects_indivisible_group(self, rng):
        qb = jnp.asarray(rng.rand(8, 15).astype(np.float32))
        with pytest.raises(AssertionError):
            lsh.group_sample_fuse(qb, qb, jnp.arange(15), 2)
