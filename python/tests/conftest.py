import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture
def rng():
    return np.random.RandomState(0)


def make_qkv(rng, n, d, dist="uniform"):
    """The paper's synthesized workload: elements iid uniform(0,1)."""
    if dist == "uniform":
        gen = lambda: rng.rand(n, d).astype(np.float32)
    else:
        gen = lambda: rng.standard_normal((n, d)).astype(np.float32)
    return gen(), gen(), gen()
